"""Schedule-memoization + multi-tenant serving runtime tests (DESIGN.md §12).

Covers: bit-identical cached-replay vs cold-lowered execution across
node x device grids (reductions included), the zero-lowering guarantee on
cache hits (TDAG/IDAG lifetime counters frozen), signature invalidation
(every near-identical resubmission that must NOT reuse a cached window),
cross-tenant buffer isolation (PermissionError at lowering time), fair
interleaving + per-tenant admission control, and bounded runtime state
under a multi-tenant soak (arbiter/executor maps must not grow with the
window count).
"""

import threading

import numpy as np
import pytest

from repro.core import (Runtime, ServingRuntime, all_range, one_to_one,
                        read, read_write, reduction, window_signature)
from repro.core.memo import _Call
from repro.core.region import Box
from repro.core.task_graph import TaskType

GRIDS = [(1, 1), (2, 2), (3, 1)]
N = 12


def step_kernel(chunk, v):
    v.set(chunk, v.get(chunk) * 1.0001 + 1.0)


def step_oracle(a):
    return a * 1.0001 + 1.0


def red_kernel(chunk, v, acc):
    x = v.get(chunk)
    s = float(x.sum())
    v.set(chunk, x + 0.5)
    acc.contribute(s)


# -- bit-identical replay vs cold lowering ------------------------------------
@pytest.mark.parametrize("nodes,devs", GRIDS)
def test_replay_bit_identical(nodes, devs):
    """Windows 1..K: the later ones replay the cached template and must
    produce exactly the bytes the cold-lowered windows produce."""
    a0 = np.arange(N * N, dtype=np.float64).reshape(N, N)
    with ServingRuntime(nodes, devs) as srv:
        t = srv.tenant("t0")
        buf = t.buffer((N, N), init=a0, name="A")
        want = a0.copy()
        for w in range(8):
            t.submit("step", (N, N), [read_write(buf, one_to_one())],
                     step_kernel)
            t.run()
            want = step_oracle(want)
            got = t.gather(buf)
            assert got.dtype == want.dtype
            assert np.array_equal(got, want), f"window {w}"
        assert t.replayed_windows > 0          # later windows were replays
        assert srv.memo_stats()["hits"] > 0


@pytest.mark.parametrize("nodes,devs", GRIDS)
def test_replay_bit_identical_reduction(nodes, devs):
    """Reduction windows (scratch alloc/free + gather/fold traffic) replay
    bit-identically: the fold order the template captured is replayed."""
    a0 = np.arange(N, dtype=np.float64)
    with ServingRuntime(nodes, devs) as srv:
        t = srv.tenant("t0")
        buf = t.buffer((N,), init=a0, name="A")
        s = t.buffer((1,), init=np.zeros(1), name="S")
        a = a0.copy()
        for w in range(8):
            t.submit("step", (N,), [read_write(buf, one_to_one()),
                                    reduction(s, "sum")], red_kernel)
            t.run()
            got_s = t.gather(s)
            assert got_s[0] == a.sum(), f"window {w}"
            a = a + 0.5
        assert np.array_equal(t.gather(buf), a)
        assert t.replayed_windows > 0
        assert srv.memo_stats()["unreplayable"] == 0


def test_replay_matches_plain_runtime():
    """Cross-check the serving runtime against the plain Runtime oracle on
    the same program: identical bytes, including the replayed windows."""
    a0 = np.linspace(-3, 3, N * N).reshape(N, N)
    with Runtime(2, 2) as rt:
        pb = rt.buffer((N, N), init=a0, name="P")
        for _ in range(6):
            rt.submit("step", (N, N), [read_write(pb, one_to_one())],
                      step_kernel)
        want = rt.gather(pb)
    with ServingRuntime(2, 2) as srv:
        t = srv.tenant("t0")
        sb = t.buffer((N, N), init=a0, name="P")
        for _ in range(6):
            t.submit("step", (N, N), [read_write(sb, one_to_one())],
                     step_kernel)
            t.run()
        got = t.gather(sb)
    assert np.array_equal(got, want)


# -- zero lowering on cache hits ----------------------------------------------
def test_cache_hit_performs_zero_lowering():
    """After capture, further submissions must not touch TDAG/CDAG/IDAG:
    the lifetime task and instruction counters freeze while hits accrue."""
    a0 = np.ones((N, N))
    with ServingRuntime(2, 1) as srv:
        t = srv.tenant("t0")
        buf = t.buffer((N, N), init=a0, name="A")

        def window():
            t.submit("step", (N, N), [read_write(buf, one_to_one())],
                     step_kernel)
            t.run().wait()

        for _ in range(4):                     # warm to the digest fixpoint
            window()
        t.drain()
        assert t.replayed_windows > 0, "template was never captured"
        tasks0 = t.tdag.task_count
        instrs0 = sum(g.emitted_count for g in t.idags)
        hits0 = srv.memo_stats()["hits"]
        for _ in range(5):
            window()
        t.drain()
        assert t.tdag.task_count == tasks0          # no TDAG work
        assert sum(g.emitted_count for g in t.idags) == instrs0  # no IDAG work
        assert srv.memo_stats()["hits"] == hits0 + 5
        # and the replays still computed the right thing
        assert np.array_equal(t.gather(buf)[0, 0],
                              np.float64(_iterate(1.0, 9)))


def _iterate(x, k):
    for _ in range(k):
        x = x * 1.0001 + 1.0
    return x


def test_memo_off_never_replays():
    a0 = np.ones((N,))
    with ServingRuntime(1, 1, memo=False) as srv:
        t = srv.tenant("t0")
        buf = t.buffer((N,), init=a0)
        for _ in range(5):
            t.submit("step", (N,), [read_write(buf, one_to_one())],
                     step_kernel)
            t.run()
        t.drain()
        assert t.replayed_windows == 0
        assert t.lowered_windows == 5


# -- invalidation: near-identical windows that MUST miss ----------------------
def _warm(t, buf, k=4):
    for _ in range(k):
        t.submit("step", (N,), [read_write(buf, one_to_one())], step_kernel)
        t.run()
    t.drain()


def test_miss_on_changed_range_mapper():
    a0 = np.arange(N, dtype=np.float64)
    with ServingRuntime(2, 1) as srv:
        t = srv.tenant("t0")
        buf = t.buffer((N,), init=a0, name="A")
        out = t.buffer((N,), init=np.zeros(N), name="O")
        _warm(t, buf)
        assert t.replayed_windows > 0
        misses0 = srv.memo_stats()["misses"]

        def narrow(chunk, s, d):             # reads own chunk only
            d.set(chunk, s.get(chunk) * 2.0)

        def widened(chunk, s, d):            # reads ALL, writes own chunk
            d.set(chunk, np.full(tuple(b - a for a, b in
                                       zip(chunk.min, chunk.max)),
                                 float(s.get(Box((0,), (N,))).sum())))

        t.submit("proj", (N,), [read(buf, one_to_one()),
                                read_write(out, one_to_one())], narrow)
        t.run()
        t.drain()
        misses1 = srv.memo_stats()["misses"]
        assert misses1 == misses0 + 1
        # same buffers, same task name — only the read range mapper widens
        t.submit("proj", (N,), [read(buf, all_range()),
                                read_write(out, one_to_one())], widened)
        t.run()
        t.drain()
        assert srv.memo_stats()["misses"] == misses1 + 1
        want = np.full(N, _warm_oracle(a0, 4).sum())
        assert np.array_equal(t.gather(out), want)


def _warm_oracle(a, k):
    for _ in range(k):
        a = step_oracle(a)
    return a


def test_miss_on_changed_granularity():
    """Same kernel, same ranges — different chunking hint must re-lower
    (the per-node/per-device chunk evaluation differs)."""
    a0 = np.arange(N, dtype=np.float64)
    with ServingRuntime(2, 1) as srv:
        t = srv.tenant("t0")
        buf = t.buffer((N,), init=a0, name="A")
        _warm(t, buf)
        misses0 = srv.memo_stats()["misses"]
        t.submit("step", (N,), [read_write(buf, one_to_one())],
                 step_kernel, granularity=(3,))
        t.run()
        t.drain()
        assert srv.memo_stats()["misses"] == misses0 + 1
        assert np.array_equal(t.gather(buf), _warm_oracle(a0, 5))


def test_miss_on_changed_reduction():
    """sum -> max and include_current_value toggles each miss, and each
    computes the right value."""
    a0 = np.arange(N, dtype=np.float64)
    with ServingRuntime(2, 1) as srv:
        t = srv.tenant("t0")
        buf = t.buffer((N,), init=a0, name="A")
        s = t.buffer((1,), init=np.zeros(1), name="S")

        def ksum(chunk, v, acc):
            acc.contribute(float(v.get(chunk).sum()))

        def kmax(chunk, v, acc):
            acc.contribute(float(v.get(chunk).max()))

        for _ in range(4):
            t.submit("r", (N,), [read(buf, one_to_one()),
                                 reduction(s, "sum")], ksum)
            t.run()
        t.drain()
        misses0 = srv.memo_stats()["misses"]
        t.submit("r", (N,), [read(buf, one_to_one()),
                             reduction(s, "max")], kmax)
        t.run()
        assert t.gather(s)[0] == a0.max()
        t.submit("r", (N,), [read(buf, one_to_one()),
                             reduction(s, "sum",
                                       include_current_value=True)], ksum)
        t.run()
        assert t.gather(s)[0] == a0.max() + a0.sum()
        assert srv.memo_stats()["misses"] >= misses0 + 2


def _mk_call(granularity=(1,)):
    buf_like = type("B", (), {})
    return _Call("k", Box((0,), (N,)), (), None, TaskType.KERNEL, (0,),
                 granularity)


def test_signature_covers_grid_budgets_namespace():
    """The canonical signature must differ across grid shape, memory
    budgets and tenant namespace (each is a separate cache universe)."""
    base = dict(num_nodes=2, devices_per_node=2,
                config=(True, True, True, True, 4, True),
                budgets={3: 1 << 20}, namespace="a")
    sig = window_signature([_mk_call()], **base)
    assert sig == window_signature([_mk_call()], **base)   # deterministic
    for change in (dict(num_nodes=3), dict(devices_per_node=1),
                   dict(budgets={3: 1 << 21}), dict(budgets=None),
                   dict(namespace="b"),
                   dict(config=(True, True, True, True, 8, True))):
        assert sig != window_signature([_mk_call()], **{**base, **change}), \
            change
    assert sig != window_signature([_mk_call(granularity=(2,))], **base)
    assert sig != window_signature([_mk_call(), _mk_call()], **base)


# -- multi-tenancy ------------------------------------------------------------
def test_cross_tenant_buffer_rejected():
    """A tenant lowering against another tenant's buffer handle must fail
    at lowering time with PermissionError — not corrupt the other tenant."""
    with ServingRuntime(1, 1) as srv:
        ta = srv.tenant("a")
        tb = srv.tenant("b")
        stolen = ta.buffer((N,), init=np.zeros(N), name="secret")
        tb.submit("smuggle", (N,), [read_write(stolen, one_to_one())],
                  step_kernel)
        with pytest.raises(PermissionError):
            tb.run()


def test_duplicate_tenant_name_rejected():
    with ServingRuntime(1, 1) as srv:
        srv.tenant("a")
        with pytest.raises(ValueError):
            srv.tenant("a")


def test_concurrent_tenants_isolated_and_fair():
    """Two tenants submitting concurrently from their own threads: each
    gets its own correct result, and the executor records completions for
    both (fair-share interleaving, not starvation)."""
    wins = 10
    with ServingRuntime(2, 1, max_inflight_per_tenant=8) as srv:
        results = {}

        def client(name, scale):
            t = srv.tenant(name)
            a0 = np.full(N, scale)
            buf = t.buffer((N,), init=a0, name="A")
            for _ in range(wins):
                t.submit("step", (N,), [read_write(buf, one_to_one())],
                         step_kernel)
                t.run()
            results[name] = (t.gather(buf), _warm_oracle(a0, wins))

        threads = [threading.Thread(target=client, args=(f"t{i}", 1.0 + i))
                   for i in range(2)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        for name, (got, want) in results.items():
            assert np.array_equal(got, want), name
        for ex in srv.executors:
            assert set(ex.tenant_done) == {"t0", "t1"}
            for n, cnt in ex.tenant_done.items():
                assert cnt > 0, n
            # admission bookkeeping drained: nothing deferred or in flight
            assert ex._deferred_count == 0
            assert all(v == 0 for v in ex._tenant_inflight.values())


def test_soak_bounded_state():
    """Many windows across two tenants: per-transfer arbiter state and
    executor epoch tokens must not accumulate (the serving process runs
    an unbounded window stream)."""
    wins = 25
    with ServingRuntime(2, 1) as srv:
        tenants = []
        for i in range(2):
            t = srv.tenant(f"t{i}")
            buf = t.buffer((N,), init=np.full(N, float(i + 1)), name="A")
            tenants.append((t, buf))
        for w in range(wins):
            for t, buf in tenants:
                t.submit("step", (N,), [read_write(buf, one_to_one())],
                         step_kernel)
                t.run()
        for i, (t, buf) in enumerate(tenants):
            t.drain()
            assert np.array_equal(t.gather(buf),
                                  _warm_oracle(np.full(N, float(i + 1)),
                                               wins))
        for ex in srv.executors:
            # completed-transfer coverage regions were popped
            assert len(ex.arbiter.received) == 0
            # WindowHandle.wait forgets its epoch token; only gather/drain
            # epochs the tenants never waited on may remain, bounded by the
            # inflight cap — not by the total window count
            assert len(ex._completed_epochs) <= 2 * 8 + 2
            assert not ex._blocked
        for t, _ in tenants:
            # replays dominate: per-tenant lowering happened O(1) times,
            # not O(windows)
            assert t.replayed_windows >= wins - 4
            assert t.lowered_windows <= 8
