"""End-to-end behaviour tests: the three paper applications executed on the
full concurrent runtime (main thread / scheduler threads / executors /
backend lanes) across rank x device grids, validated against numpy oracles.
"""

import numpy as np
import pytest

from repro.core import (BoundsError, Box, Runtime, all_range, fixed,
                        neighborhood, one_to_one, read, read_write, write)
from repro.core.task_graph import TaskType

GRIDS = [(1, 1), (1, 2), (2, 1), (2, 2), (3, 2)]


# -- N-body (paper listing 1 / fig. 2 / fig. 4) ------------------------------
def nbody_oracle(P0, V0, steps, dt=0.01, M=1.0):
    P, V = P0.copy(), V0.copy()
    for _ in range(steps):
        d = P[None, :, :] - P[:, None, :]
        r2 = (d * d).sum(-1) + 1e-3
        F = (d / r2[..., None] ** 1.5).sum(1)
        V = V + M * F * dt
        P = P + V * dt
    return P, V


def run_nbody(num_nodes, devs, N=48, steps=3, lookahead=True, dt=0.01, M=1.0):
    rng = np.random.default_rng(7)
    P0 = rng.normal(size=(N, 3))
    V0 = rng.normal(size=(N, 3)) * 0.1
    with Runtime(num_nodes=num_nodes, devices_per_node=devs,
                 lookahead=lookahead) as rt:
        P = rt.buffer((N, 3), init=P0, name="P")
        V = rt.buffer((N, 3), init=V0, name="V")

        def timestep(chunk, p_view, v_view):
            Pa = p_view.get(Box((0, 0), (N, 3)))
            d = Pa[None, :, :] - Pa[chunk.min[0]:chunk.max[0], None, :]
            r2 = (d * d).sum(-1) + 1e-3
            F = (d / r2[..., None] ** 1.5).sum(1)
            v_view.set(chunk, v_view.get(chunk) + M * F * dt)

        def update(chunk, v_view, p_view):
            p_view.set(chunk, p_view.get(chunk) + v_view.get(chunk) * dt)

        for _ in range(steps):
            rt.submit("timestep", (N, 3),
                      [read(P, all_range()), read_write(V, one_to_one())],
                      timestep)
            rt.submit("update", (N, 3),
                      [read(V, one_to_one()), read_write(P, one_to_one())],
                      update)
        Pg, Vg = rt.gather(P), rt.gather(V)
        assert rt.warnings == []
    return (Pg, Vg), nbody_oracle(P0, V0, steps, dt, M)


@pytest.mark.parametrize("nodes,devs", GRIDS)
def test_nbody(nodes, devs):
    (Pg, Vg), (Pe, Ve) = run_nbody(nodes, devs)
    np.testing.assert_allclose(Pg, Pe, atol=1e-10)
    np.testing.assert_allclose(Vg, Ve, atol=1e-10)


def test_nbody_without_lookahead_matches():
    (Pg, Vg), (Pe, Ve) = run_nbody(2, 2, lookahead=False)
    np.testing.assert_allclose(Pg, Pe, atol=1e-10)


# -- WaveSim: 5-point stencil (paper §5) --------------------------------------
def wavesim_oracle(u0, u1, steps, c=0.25):
    um, u = u0.copy(), u1.copy()
    for _ in range(steps):
        lap = (np.roll(u, 1, 0) + np.roll(u, -1, 0) +
               np.roll(u, 1, 1) + np.roll(u, -1, 1) - 4 * u)
        un = 2 * u - um + c * lap
        un[0, :] = un[-1, :] = un[:, 0] = un[:, -1] = 0.0
        um, u = u, un
    return u


@pytest.mark.parametrize("nodes,devs", [(1, 1), (2, 2), (4, 1)])
def test_wavesim(nodes, devs, H=32, W=24, steps=4):
    rng = np.random.default_rng(3)
    u0 = np.zeros((H, W))
    u1 = rng.normal(size=(H, W)) * 0.01
    u1[0, :] = u1[-1, :] = u1[:, 0] = u1[:, -1] = 0.0
    c = 0.25

    def step_kernel(chunk, um_v, u_v, un_v):
        lo, hi = chunk.min[0], chunk.max[0]
        ext = Box((max(0, lo - 1), 0), (min(H, hi + 1), W))
        u = u_v.get(ext)
        um = um_v.get(chunk)
        pad = lo - ext.min[0]
        out = np.empty((hi - lo, W))
        for r in range(hi - lo):
            g = r + pad
            gi = lo + r
            if gi == 0 or gi == H - 1:
                out[r] = 0.0
                continue
            row = u[g]
            lap = (u[g - 1] + u[g + 1] + np.roll(row, 1) + np.roll(row, -1)
                   - 4 * row)
            out[r] = 2 * row - um[r] + c * lap
            out[r, 0] = out[r, -1] = 0.0
        un_v.set(chunk, out)

    with Runtime(num_nodes=nodes, devices_per_node=devs) as rt:
        B = [rt.buffer((H, W), init=u0, name="um"),
             rt.buffer((H, W), init=u1, name="u"),
             rt.buffer((H, W), init=np.zeros((H, W)), name="un")]
        for s in range(steps):
            um, u, un = B[s % 3], B[(s + 1) % 3], B[(s + 2) % 3]
            rt.submit(f"wave{s}", (H, W),
                      [read(um, one_to_one()), read(u, neighborhood((1, 0))),
                       write(un, one_to_one())], step_kernel)
        result = rt.gather(B[(steps + 1) % 3])
        assert rt.warnings == []
    np.testing.assert_allclose(result, wavesim_oracle(u0, u1, steps, c),
                               atol=1e-10)


# -- RSim: growing access pattern (paper §4.3/§5) -----------------------------
def row_cols(t):
    """Write mapper: row ``t``, columns one-to-one with the chunk (so the
    per-device writer sets stay disjoint under a column split)."""
    from repro.core.region import Region

    def rm(chunk, shape):
        return Region.from_box(Box((t, chunk.min[1]), (t + 1, chunk.max[1])))

    rm.__name__ = f"row_cols({t})"
    return rm


def run_rsim(nodes, devs, lookahead, T=10, W=16):
    with Runtime(num_nodes=nodes, devices_per_node=devs,
                 lookahead=lookahead) as rt:
        R = rt.buffer((T, W), init=np.zeros((T, W)), name="R")
        for t in range(T):
            def radiosity(chunk, prev_v, row_v, t=t):
                if t == 0:
                    row = np.ones(W)
                else:
                    row = prev_v.get(Box((0, 0), (t, W))).sum(0) + 1.0
                row_v.set(Box((t, chunk.min[1]), (t + 1, chunk.max[1])),
                          row[chunk.min[1]:chunk.max[1]])
            rt.submit(f"rad{t}", Box((0, 0), (1, W)),
                      [read(R, fixed(Box((0, 0), (max(t, 1), W)))),
                       write(R, row_cols(t))],
                      radiosity, split_dims=(1,))
        out = rt.gather(R)
        allocs = rt.total_allocs()
    exp = np.zeros((T, W))
    exp[0] = 1.0
    for t in range(1, T):
        exp[t] = exp[:t].sum(0) + 1.0
    return out, exp, allocs


def test_rsim_lookahead_correct_and_alloc_free():
    out, exp, allocs_on = run_rsim(1, 2, lookahead=True)
    np.testing.assert_allclose(out, exp)
    out2, exp2, allocs_off = run_rsim(1, 2, lookahead=False)
    np.testing.assert_allclose(out2, exp2)
    assert allocs_on < allocs_off, "lookahead must elide resize allocations"


# -- debug facilities (paper §4.4) --------------------------------------------
def test_uninitialized_read_warning_runtime():
    with Runtime(1, 1) as rt:
        B = rt.buffer((8,), name="u")  # never initialized
        rt.submit("r", (8,), [read(B, one_to_one())], lambda c, v: None)
        rt.sync()
        assert any("uninitialized" in w for w in rt.warnings)


def test_overlapping_write_error_runtime():
    with Runtime(2, 1) as rt:
        B = rt.buffer((8,), name="o")
        rt.submit("bad", (8,), [write(B, all_range())],
                  lambda c, v: v.set(Box((0,), (8,)), 1.0))
        rt.sync()
        assert any("overlapping write" in w for w in rt.warnings)


def test_accessor_bounds_check():
    with Runtime(1, 1, check_bounds=True) as rt:
        B = rt.buffer((16,), init=np.zeros(16), name="b")

        def oob(chunk, v):
            v.get(Box((0,), (16,)))  # declared only one_to_one on chunk

        rt.submit("half", (8,), [read_write(B, one_to_one())], oob)
        with pytest.raises((RuntimeError, BoundsError)):
            rt.sync()


# -- scheduling/execution overlap (paper fig. 7) -------------------------------
def test_scheduler_overlaps_execution():
    import time
    with Runtime(1, 2, trace=True) as rt:
        B = rt.buffer((64,), init=np.zeros(64), name="B")

        def slowk(chunk, v):
            time.sleep(0.002)
            v.set(chunk, v.get(chunk) + 1)

        for i in range(30):
            rt.submit(f"k{i}", (64,), [read_write(B, one_to_one())], slowk)
        rt.sync()
        tr = rt.tracer
    lanes = tr.lanes()
    assert any(l.startswith("sched-") for l in lanes)
    assert any(".device" in l for l in lanes), lanes.keys()
    # overlap fraction is computable (magnitude asserted in benchmarks)
    assert tr.overlap_fraction("sched-N0", "N0.device") >= 0.0


# -- host tasks, epochs, gather -------------------------------------------------
def test_host_task_and_epoch():
    seen = []
    with Runtime(2, 1) as rt:
        B = rt.buffer((8,), init=np.arange(8.0), name="B")

        def host(chunk, v):
            seen.append((chunk.min[0], chunk.max[0]))

        rt.submit("h", (8,), [read(B, one_to_one())], host,
                  ttype=TaskType.HOST)
        rt.sync()
    assert sorted(seen) == [(0, 4), (4, 8)]


def test_many_buffers_many_tasks():
    """Stress: 8 buffers, 40 random copy tasks, 2x2 grid, vs mirror arrays."""
    rng = np.random.default_rng(5)
    n = 32
    with Runtime(2, 2) as rt:
        bufs = [rt.buffer((n,), init=np.zeros(n), name=f"b{i}")
                for i in range(8)]
        mirror = [np.zeros(n) for _ in range(8)]
        for step in range(40):
            i, j = rng.integers(0, 8, size=2)
            if i == j:
                continue

            def k(chunk, src, dst):
                dst.set(chunk, src.get(chunk) * 0.5 + 1.0)

            rt.submit(f"t{step}", (n,),
                      [read(bufs[i], one_to_one()),
                       write(bufs[j], one_to_one())], k)
            mirror[j] = mirror[i] * 0.5 + 1.0
        got = [rt.gather(b) for b in bufs]
    for g, m in zip(got, mirror):
        np.testing.assert_allclose(g, m)


# -- straggler detection hook ----------------------------------------------------
def test_straggler_report():
    import time
    with Runtime(1, 2) as rt:
        B = rt.buffer((16,), init=np.zeros(16), name="B")

        def slow_on_high(chunk, v):
            if chunk.min[0] >= 8:
                time.sleep(0.01)
            v.set(chunk, v.get(chunk) + 1)

        for i in range(5):
            rt.submit(f"k{i}", (16,), [read_write(B, one_to_one())],
                      slow_on_high)
        rt.sync()
        rep = rt.executors[0].straggler_report()
    assert any(k.startswith("device") for k in rep), rep
