"""Flight-recorder tests (DESIGN.md §11): metrics registry, wait-state
attribution, and the critical-path analyzer.

The load-bearing invariant is the exact wait decomposition: for every
traced instruction, the classified pending wait plus the queue wait must
reconstruct the measured issue latency (``t_start - t_reg``) — the
histograms are then sums of true durations, not estimates.  The
critical-path walk must likewise never over-account: its layer + wait
totals are interval-disjoint by construction and bounded by the
end-to-end time.
"""

import threading

import numpy as np
import pytest

from repro.core import (Histogram, MetricsRegistry, Runtime, Tracer,
                        classify_wait, critical_path, one_to_one, read,
                        read_write, reduction)
from repro.core.instructions import InstructionType
from repro.core.observability import (WAIT_BUDGET, WAIT_CLASSES, WAIT_DEP,
                                      WAIT_QUEUE, WAIT_TRANSPORT)


# -- histograms ---------------------------------------------------------------

def test_histogram_basic_stats():
    h = Histogram()
    for v in (1.0, 2.0, 4.0, 8.0, 100.0):
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 5
    assert s["sum_us"] == pytest.approx(115.0)
    assert s["max_us"] == 100.0
    assert 0.0 < s["p50"] <= s["p95"] <= s["p99"] <= s["max_us"]


def test_histogram_percentile_bucket_bounds():
    h = Histogram()
    for _ in range(100):
        h.observe(10.0)               # bucket [8, 16)
    assert 8.0 <= h.percentile(50) < 16.0
    assert h.percentile(99) <= h.vmax == 10.0


def test_histogram_empty_and_overflow():
    h = Histogram()
    assert h.percentile(50) == 0.0
    h.observe(1e12)                   # beyond the last bucket: clamped
    assert h.snapshot()["count"] == 1
    assert h.percentile(99) <= h.vmax


# -- registry -----------------------------------------------------------------

def test_registry_counters_gauges_histograms():
    m = MetricsRegistry()
    m.counter("comm.drops")
    m.counter("comm.drops", 2.0)
    m.gauge("executor.N0.inflight", 5.0)
    m.gauge("executor.N0.inflight", 3.0)   # last write wins
    m.observe("executor.N0.issue_us", 12.0)
    assert m.histogram("executor.N0.issue_us") is \
        m.histogram("executor.N0.issue_us")
    s = m.snapshot()
    assert s["counters"]["comm.drops"] == 3.0
    assert s["gauges"]["executor.N0.inflight"] == 3.0
    assert s["histograms"]["executor.N0.issue_us"]["count"] == 1


def test_registry_thread_safety():
    m = MetricsRegistry()

    def spin():
        for _ in range(1000):
            m.counter("c")
            m.gauge("g", 1.0)
            m.observe("h", 1.0)

    ts = [threading.Thread(target=spin) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    s = m.snapshot()
    assert s["counters"]["c"] == 4000.0


def test_registry_export_counters_to_tracer():
    m = MetricsRegistry()
    m.counter("memory.N0.spills", 7.0)
    m.gauge("sched.N0.horizon_lag", 2.0)
    tr = Tracer()
    m.export_counters(tr)
    assert tr.counters["memory.N0.spills"][-1][1] == 7.0
    assert tr.counters["sched.N0.horizon_lag"][-1][1] == 2.0


# -- wait taxonomy ------------------------------------------------------------

def test_classify_wait_taxonomy():
    assert classify_wait(None) == WAIT_DEP
    assert classify_wait(InstructionType.DEVICE_KERNEL) == WAIT_DEP
    assert classify_wait(InstructionType.FREE) == WAIT_BUDGET
    assert classify_wait(InstructionType.SPILL) == WAIT_BUDGET
    assert classify_wait(InstructionType.RELOAD) == WAIT_BUDGET
    assert classify_wait(InstructionType.SEND) == WAIT_TRANSPORT
    assert classify_wait(InstructionType.COLL_RECV) == WAIT_TRANSPORT
    assert WAIT_QUEUE in WAIT_CLASSES


# -- live-run attribution -----------------------------------------------------

def _run_traced(num_nodes=2, devices_per_node=2, steps=6, **kw):
    rt = Runtime(num_nodes=num_nodes, devices_per_node=devices_per_node,
                 trace=True, **kw)
    N = 64
    a = rt.buffer((N, N), init=np.ones((N, N)), name="A")
    b = rt.buffer((N, N), init=np.zeros((N, N)), name="B")
    E = rt.buffer((1,), init=np.zeros(1), name="E")

    def fwd(chunk, av, bv):
        bv.set(chunk, av.get(chunk) * 1.001)

    def bwd(chunk, bv, av):
        av.set(chunk, bv.get(chunk) * 0.999)

    def energy(chunk, av, red):
        red.contribute(av.get(chunk).sum())

    for i in range(steps):
        rt.submit(f"fwd{i}", (N, N),
                  [read(a, one_to_one()), read_write(b, one_to_one())], fwd)
        rt.submit(f"bwd{i}", (N, N),
                  [read(b, one_to_one()), read_write(a, one_to_one())], bwd)
    rt.submit("energy", (N, N),
              [read(a, one_to_one()), reduction(E, "sum")], energy)
    rt.sync()
    return rt


def test_records_wait_sum_is_exact():
    rt = _run_traced()
    try:
        recs = rt.tracer.records
        assert recs, "traced run produced no instruction records"
        for r in recs:
            assert r.t_reg <= r.t_ready + 1e-9
            assert r.t_ready <= r.t_start + 1e-9
            assert r.t_start <= r.t_done + 1e-9
            lat = r.t_start - r.t_reg
            parts = (r.t_ready - r.t_reg) + (r.t_start - r.t_ready)
            # exact by construction: within 1% (and an absolute epsilon
            # for ~0 latencies)
            assert abs(parts - lat) <= 1e-9 + 0.01 * max(lat, 1e-12)
            assert r.wait_cls in WAIT_CLASSES
    finally:
        rt.shutdown()


def test_records_carry_trace_context():
    rt = _run_traced()
    try:
        kernels = [r for r in rt.tracer.records if r.kind == "device_kernel"]
        assert kernels
        for r in kernels:
            assert r.tid is not None and r.cid is not None
        # iids are only unique per node: both nodes must be present
        assert {r.node for r in rt.tracer.records} == {0, 1}
    finally:
        rt.shutdown()


def test_critical_path_report_is_consistent():
    rt = _run_traced()
    try:
        rep = critical_path(rt.tracer)
        assert rep.total_us > 0
        assert rep.chain_len >= 1
        assert rep.n_instructions == len(rt.tracer.records)
        assert 0.0 <= rep.scheduler_fraction <= 1.0
        accounted = sum(rep.by_layer.values()) + sum(rep.by_wait.values())
        # the frontier-clipped walk never over-accounts
        assert accounted <= rep.total_us * (1 + 1e-6)
        assert rep.unattributed_us == pytest.approx(
            rep.total_us - accounted, rel=1e-6, abs=1e-3)
        text = rep.render()
        assert "critical path:" in text
        assert "scheduler share of critical path" in text
        d = rep.as_dict()
        assert d["total_us"] == rep.total_us
        assert rt.critical_path_report().total_us > 0
    finally:
        rt.shutdown()


def test_critical_path_empty_tracer():
    rep = critical_path(Tracer())
    assert rep.total_us == 0.0 and rep.chain_len == 0


def test_runtime_metrics_snapshot_unified():
    rt = _run_traced()
    try:
        snap = rt.metrics()
        for key in ("counters", "gauges", "histograms", "comm", "memory",
                    "lookahead", "executor", "instants"):
            assert key in snap, key
        h = snap["histograms"]
        # per-node issue-latency + wait-class histograms (naming scheme
        # layer.node.name)
        for n in (0, 1):
            assert h[f"executor.N{n}.issue_us"]["count"] > 0
            for cls in WAIT_CLASSES:
                assert f"executor.N{n}.wait_{cls}_us" in h
        g = snap["gauges"]
        assert "executor.N0.inflight" in g
        assert "lookahead.N0.queued" in g
        assert "sched.N0.horizon_lag" in g
        # issue histogram sums match the per-record ground truth
        recs = rt.tracer.records
        for n in (0, 1):
            hist_sum = h[f"executor.N{n}.issue_us"]["sum_us"]
            rec_sum = sum((r.t_start - r.t_reg) * 1e6
                          for r in recs if r.node == n)
            assert hist_sum == pytest.approx(rec_sum, rel=0.01)
            assert h[f"executor.N{n}.issue_us"]["count"] == \
                sum(1 for r in recs if r.node == n)
    finally:
        rt.shutdown()


def test_runtime_metrics_disabled_still_works():
    rt = Runtime(num_nodes=1, devices_per_node=1, metrics=False)
    try:
        B = rt.buffer((8,), init=np.zeros(8), name="b")
        rt.submit("k", (8,), [read_write(B, one_to_one())],
                  lambda c, v: v.set(c, v.get(c) + 1))
        rt.sync()
        assert rt.metrics_registry is None
        snap = rt.metrics()
        assert snap["counters"] == {} and snap["histograms"] == {}
        assert "memory" in snap and "comm" in snap
        # zero-instrumentation executors skip every stamp
        assert rt.executors[0]._obs is False
    finally:
        rt.shutdown()
