"""Out-of-order issue via allocation renaming (DESIGN.md §13).

Structural: a renamed IDAG carries no anti/output dependency edges between
real instructions (pure overwrites rebind to fresh physicals; recycled-
physical hazards compact onto sync instructions).  The free pool bounds
live physicals: recycling keeps ALLOC counts flat over iteration, and under
a device budget pooled physicals drain before any spill.  Semantics: a
renamed run is bit-identical to the renaming-off oracle on 1x1 / 2x2 / 3x1
grids, reductions included, under chaos transport faults and under spill
pressure.  Serving side: pipelined replay keeps >= 2 replayed windows of
one tenant in flight (bit-identical to depth-1), the memo cache honors its
LRU cap, and repeated gathers replay one pinned collection buffer.
"""

import threading
import time

import numpy as np

from repro.core import (FaultPlan, IdagGenerator, InstructionType, Runtime,
                        TaskGraph, all_range, generate_cdag, one_to_one,
                        read, read_write, reduction, write)
from repro.core.allocation import device_memory
from repro.core.buffer import VirtualBuffer
from repro.core.command_graph import CommandType
from repro.core.memo import ServingRuntime
from repro.core.region import Box
from repro.core.task_graph import DepKind

N = 32
_SYNC = (InstructionType.HORIZON, InstructionType.EPOCH)


# --------------------------------------------------------------------------
# structural: renamed IDAGs carry no real anti-dependency edges
# --------------------------------------------------------------------------
def _compile(tdag, idag):
    gen = generate_cdag(tdag, 1)
    for cmd in gen.commands[0]:
        if cmd.ctype == CommandType.EPOCH and cmd.task is None:
            continue
        idag.compile(cmd)
    return idag.instructions


def _iterative_tdag(steps=6):
    """Read-then-overwrite per step: every overwrite is a WAR hazard against
    the step's reader and a WAW hazard against the previous overwrite."""
    tdag = TaskGraph(horizon_step=2)
    B = VirtualBuffer((N,), name="B", initial_value=np.zeros(N))
    C = VirtualBuffer((N,), name="C")
    for s in range(steps):
        tdag.submit(f"r{s}", (N,), [read(B, one_to_one()),
                                    write(C, one_to_one())])
        tdag.submit(f"w{s}", (N,), [write(B, one_to_one())])
    return tdag, B


def _hazard_edges(instrs):
    """(instr, dep, kind) for every ANTI/OUTPUT edge between real (non-sync)
    instructions — exactly the in-order serialization renaming removes."""
    out = []
    for i in instrs:
        for d, k in i.dependencies:
            if (k in (DepKind.ANTI, DepKind.OUTPUT)
                    and i.itype not in _SYNC and d.itype not in _SYNC):
                out.append((i, d, k))
    return out


def test_renamed_idag_has_no_anti_edges():
    tdag, _ = _iterative_tdag()
    plain = _compile(tdag, IdagGenerator(0, 1))
    assert _hazard_edges(plain), "oracle IDAG should carry WAR/WAW edges"

    tdag, _ = _iterative_tdag()
    idag = IdagGenerator(0, 1, renaming=True)
    renamed = _compile(tdag, idag)
    assert _hazard_edges(renamed) == []
    assert idag.mem.stats.renames > 0


def test_free_pool_bounds_physicals():
    """Recycling keeps the physical count flat: 6 overwrites materialize at
    most two physicals per (buffer, memory) — the live one and one pooled —
    instead of one fresh ALLOC per write."""
    tdag, B = _iterative_tdag(steps=6)
    idag = IdagGenerator(0, 1, renaming=True)
    instrs = _compile(tdag, idag)
    allocs_B = [i for i in instrs if i.itype == InstructionType.ALLOC
                and i.allocation.bid == B.bid]
    assert idag.mem.stats.renames >= 6
    assert idag.mem.stats.pool_hits > 0
    # initial materialization + at most one rename-fresh physical per memory
    by_mid = {}
    for i in allocs_B:
        by_mid.setdefault(i.allocation.mid, []).append(i)
    assert all(len(v) <= 2 for v in by_mid.values()), by_mid


# --------------------------------------------------------------------------
# end-to-end: bit-identical to the renaming-off oracle
# --------------------------------------------------------------------------
def _wave_program(q, steps=6):
    """Rotating-buffer wave iteration with a per-step sum reduction; the
    all_range read forces cross-node exchange on multi-node grids."""
    rng = np.random.default_rng(11)
    u0 = q.buffer((N,), init=rng.normal(size=N), name="u0")
    u1 = q.buffer((N,), init=np.zeros(N), name="u1")
    E = q.buffer((1,), init=np.zeros(1), name="E")
    cur, nxt = u0, u1
    energies = []
    for s in range(steps):
        def step(chunk, uc, un, _s=s):
            ua = uc.get(Box((0,), (N,)))
            lo, hi = chunk.min[0], chunk.max[0]
            lap = np.roll(ua, 1) + np.roll(ua, -1) - 2.0 * ua
            un.set(chunk, (ua + 0.1 * lap + 0.01 * _s)[lo:hi])

        q.submit(f"step{s}", (N,), [read(cur, all_range()),
                                    write(nxt, one_to_one())], step)

        def esum(chunk, un, red):
            red.contribute(un.get(chunk))

        q.submit(f"E{s}", (N,), [read(nxt, one_to_one()),
                                 reduction(E, "sum")], esum)
        energies.append(float(q.gather(E)[0]))
        cur, nxt = nxt, cur
    return q.gather(cur), energies


def test_renaming_bit_identical_oracle():
    for nodes, devs in [(1, 1), (2, 2), (3, 1)]:
        with Runtime(nodes, devs) as q:
            base, e_base = _wave_program(q)
            assert q.warnings == [], q.warnings
        with Runtime(nodes, devs, renaming=True, issue_width=8,
                     max_inflight_windows=4) as q:
            out, e_out = _wave_program(q)
            renames = sum(r["renames"] for r in q.memory_report())
            assert q.warnings == [], q.warnings
        np.testing.assert_array_equal(base, out)
        assert e_base == e_out
        assert renames > 0, (nodes, devs)


def test_renaming_bit_identical_under_chaos():
    plan = FaultPlan(seed=5, drop=0.4, duplicate=0.2, delay=0.2)
    with Runtime(2, 1) as q:
        base, e_base = _wave_program(q, steps=4)
    with Runtime(2, 1, renaming=True, fault_plan=plan) as q:
        out, e_out = _wave_program(q, steps=4)
        retries = q.comm_stats()["retries"]
    np.testing.assert_array_equal(base, out)
    assert e_base == e_out
    assert retries > 0          # the chaos plan actually bit


def _phased_overwrites(q, groups=3, steps=4, n=4096):
    """``groups`` (A, B) pairs touched in phases; every step is a pure
    overwrite of B (a rename candidate), and phase 0 pauses around the
    others so its buffers face eviction while other phases run."""
    rng = np.random.default_rng(3)
    bufs = [(q.buffer((n,), init=rng.normal(size=n), name=f"A{g}"),
             q.buffer((n,), init=np.zeros(n), name=f"B{g}"))
            for g in range(groups)]

    def phase(g, lo, hi):
        A, B = bufs[g]
        for s in range(lo, hi):
            def k(chunk, av, bv, _s=s):
                bv.set(chunk, av.get(chunk) * (_s + 2))
            q.submit(f"g{g}s{s}", (n,), [read(A, one_to_one()),
                                         write(B, one_to_one())], k)

    phase(0, 0, steps // 2)
    for g in range(1, groups):
        phase(g, 0, steps)
    phase(0, steps // 2, steps)
    return [q.gather(B) for _, B in bufs]


def test_renaming_bit_identical_under_budget():
    """Under a 50% device budget, pooled physicals drain before spilling
    and the run stays bit-identical to the unbudgeted renaming-off oracle
    with real peaks under budget."""
    with Runtime(1, 1) as q:
        base = _phased_overwrites(q)
    with Runtime(1, 1, renaming=True) as q:
        _phased_overwrites(q)
        hwm = q.device_peak_bytes()
    budget = hwm // 2
    with Runtime(1, 1, renaming=True, device_memory_budget=budget) as q:
        out = _phased_overwrites(q)
        rep = q.memory_report()[0]
        peak = q.device_peak_bytes()
        assert q.warnings == [], q.warnings
    for a, b in zip(base, out):
        np.testing.assert_array_equal(a, b)
    assert peak <= budget, (peak, budget)
    assert rep["over_budget"] == 0
    assert rep["renames"] > 0
    assert rep["pool_frees"] > 0        # budget pressure drained the pool


# --------------------------------------------------------------------------
# serving: pipelined replay, LRU cap, pinned gather
# --------------------------------------------------------------------------
def _serve_burst(depth, windows=8, slow_s=0.002):
    """One tenant, two independent buffers: a fast kernel on X and a slow
    kernel on Y per window.  With depth >= 2 the next window's fast kernel
    overlaps the previous window's slow kernel."""
    with ServingRuntime(num_nodes=1, devices_per_node=1,
                        max_inflight_windows=depth) as srv:
        t = srv.tenant("t0", max_queued_windows=windows + 2)
        X = t.buffer((N,), name="X", init=np.zeros(N))
        Y = t.buffer((N,), name="Y", init=np.arange(N, dtype=np.float64))
        for w in range(windows):
            def fast(chunk, xv, _w=w):
                xv.set(chunk, xv.get(chunk) + (_w + 1))

            def slow(chunk, yv, _w=w):
                time.sleep(slow_s)
                yv.set(chunk, yv.get(chunk) * 1.5 - _w)

            t.submit("fast", (N,), [read_write(X, one_to_one())], fast)
            t.submit("slow", (N,), [read_write(Y, one_to_one())], slow)
            t.run()
        t.drain()
        x, y = t.gather(X), t.gather(Y)
        stats = srv.memo_stats()
    return x, y, stats


def test_pipelined_replay_bit_identical_and_deep():
    x1, y1, s1 = _serve_burst(depth=1)
    x2, y2, s2 = _serve_burst(depth=2)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    t1, t2 = s1["tenants"]["t0"], s2["tenants"]["t0"]
    assert t2["replayed"] > 0
    # the pipelining-depth discriminator: depth-1 never overlaps windows,
    # depth-2 keeps at least two replayed windows concurrently in flight
    assert t1["window_peak"][0] == 1, t1["window_peak"]
    assert t2["window_peak"][0] >= 2, t2["window_peak"]


def test_memo_cache_lru_cap():
    with ServingRuntime(num_nodes=1, devices_per_node=1,
                        memo_cache_max=2) as srv:
        t = srv.tenant("t0")
        A = t.buffer((N,), name="A", init=np.zeros(N))
        # three distinct signatures, round-robin: with cap 2 the LRU entry
        # is evicted every time, so no signature ever reaches its capture
        # fixpoint — correctness is unaffected
        for cycle in range(3):
            for name in ("ka", "kb", "kc"):
                def k(chunk, av, _n=name):
                    av.set(chunk, av.get(chunk) + len(_n))
                t.submit(name, (N,), [read_write(A, one_to_one())], k)
                t.run()
        t.drain()
        out = t.gather(A)
        stats = srv.memo_stats()
        assert len(t._memo) <= 2
    np.testing.assert_array_equal(out, np.full(N, 2.0 * 9))  # 9 kernels, +2 each
    assert stats["evictions"] > 0


def test_pinned_gather_replays_and_stays_independent():
    with ServingRuntime(num_nodes=1, devices_per_node=1) as srv:
        t = srv.tenant("t0")
        A = t.buffer((N,), name="A", init=np.arange(N, dtype=np.float64))

        def bump(chunk, av):
            av.set(chunk, av.get(chunk) + 1.0)

        gathers = []
        for w in range(5):
            t.submit("bump", (N,), [read_write(A, one_to_one())], bump)
            t.run()
            gathers.append(t.gather(A))
        assert len(t._gather_pins) == 1       # one pinned target for A
    for w, g in enumerate(gathers):
        np.testing.assert_array_equal(g, np.arange(N) + (w + 1))
    # each gather returns an independent copy of the pinned buffer
    gathers[0][:] = -1.0
    np.testing.assert_array_equal(gathers[1], np.arange(N) + 2)


# --------------------------------------------------------------------------
# issue width: the drain-pass cap is semantics-neutral
# --------------------------------------------------------------------------
def test_issue_width_semantics_neutral():
    with Runtime(1, 2) as q:
        base, e_base = _wave_program(q, steps=4)
    with Runtime(1, 2, issue_width=1) as q:
        out, e_out = _wave_program(q, steps=4)
    np.testing.assert_array_equal(base, out)
    assert e_base == e_out
