"""Schedule sanitizer (DESIGN.md §14).

Unit: hand-built defect graphs produce exactly the right issue kind (race,
use-after-free, double-free, leak, deadlock, orphan receive, missing pilot,
budget mismatch).  True negatives: every corpus program (iterative
overwrite, wave + reduction, n-body) lowered on 1x1 / 2x2 / 3x1 grids with
renaming on/off — plus collective reductions, halo exchange, and
half-working-set spill graphs — verifies clean, statically and end to end
(``Runtime(verify=...)`` in both modes, chaos transport faults, budgeted
spill, and serving-runtime memo replay at pipeline depth >= 2).  Mutation
self-test: a seeded fuzzer plants one defect per graph over >= 200 mutants
and the sanitizer must detect >= 95% AND name a mutated instruction in the
report (attribution), with every mutation operator exercised.
"""

import numpy as np
import pytest

from repro.core import (Box, FaultPlan, IdagGenerator, InstructionType,
                        Runtime, TaskGraph, VerificationError, all_range,
                        generate_cdag, neighborhood, one_to_one, read,
                        read_write, reduction, run_mutation_campaign,
                        verify_graph, write)
from repro.core.allocation import Allocation, device_memory
from repro.core.buffer import VirtualBuffer
from repro.core.command_graph import CommandType
from repro.core.instructions import Instruction
from repro.core.memo import ServingRuntime
from repro.core.task_graph import DepKind

N = 32
GRIDS = [(1, 1), (2, 2), (3, 1)]
_IT = InstructionType


# --------------------------------------------------------------------------
# corpus: statically lowered programs (no execution)
# --------------------------------------------------------------------------
def _lower(tdag, nodes, devs, *, renaming=False, collectives=False,
           budgets=None):
    """Lower a TDAG for every rank; returns (node_instrs, pilots, budgets,
    peaks) — the shape ``verify_graph`` / ``run_mutation_campaign`` expect."""
    gen = generate_cdag(tdag, nodes, collectives=collectives)
    node_instrs, pilots, peaks = [], [], []
    for n in range(nodes):
        idag = IdagGenerator(n, devs, renaming=renaming, budgets=budgets)
        for cmd in gen.commands[n]:
            if cmd.ctype == CommandType.EPOCH and cmd.task is None:
                continue
            idag.compile(cmd)
        node_instrs.append(idag.instructions)
        pilots.extend(idag.pilots)
        peaks.append(dict(idag.mem.peak))
    return node_instrs, pilots, dict(budgets) if budgets else None, peaks


def _iterative_tdag(steps=6):
    tdag = TaskGraph(horizon_step=2)
    B = VirtualBuffer((N,), name="B", initial_value=np.zeros(N))
    C = VirtualBuffer((N,), name="C")
    for s in range(steps):
        tdag.submit(f"r{s}", (N,), [read(B, one_to_one()),
                                    write(C, one_to_one())])
        tdag.submit(f"w{s}", (N,), [write(B, one_to_one())])
    return tdag


def _wave_tdag(steps=6):
    tdag = TaskGraph(horizon_step=2)
    u0 = VirtualBuffer((N,), name="u0", initial_value=np.zeros(N))
    u1 = VirtualBuffer((N,), name="u1", initial_value=np.zeros(N))
    E = VirtualBuffer((1,), name="E", initial_value=np.zeros(1))
    cur, nxt = u0, u1
    for s in range(steps):
        tdag.submit(f"step{s}", (N,), [read(cur, all_range()),
                                       write(nxt, one_to_one())])
        tdag.submit(f"E{s}", (N,), [read(nxt, one_to_one()),
                                    reduction(E, "sum")])
        cur, nxt = nxt, cur
    return tdag


def _nbody_tdag(steps=4):
    tdag = TaskGraph(horizon_step=2)
    pos = VirtualBuffer((N,), name="pos", initial_value=np.zeros(N))
    frc = VirtualBuffer((N,), name="frc")
    for s in range(steps):
        tdag.submit(f"force{s}", (N,), [read(pos, all_range()),
                                        write(frc, one_to_one())])
        tdag.submit(f"euler{s}", (N,), [read(frc, one_to_one()),
                                        read_write(pos, one_to_one())])
    return tdag


def _halo_tdag(steps=5):
    tdag = TaskGraph(horizon_step=2)
    a = VirtualBuffer((N,), name="a", initial_value=np.zeros(N))
    b = VirtualBuffer((N,), name="b")
    cur, nxt = a, b
    for s in range(steps):
        tdag.submit(f"h{s}", (N,), [read(cur, neighborhood((2,))),
                                    write(nxt, one_to_one())])
        cur, nxt = nxt, cur
    return tdag


CORPUS = [("iter", _iterative_tdag), ("wave", _wave_tdag),
          ("nbody", _nbody_tdag)]


# --------------------------------------------------------------------------
# unit: hand-built defect graphs hit exactly the right check
# --------------------------------------------------------------------------
def _scratch(mid=device_memory(0), lo=0, hi=8, bid=None):
    return Allocation(mid, bid, Box((lo,), (hi,)))


def _copy_graph(*, ordered):
    """ALLOC src/dst, two COPYs writing the same dst box, FREEs.  With
    ``ordered=False`` the copies race on the dst allocation."""
    src, dst = _scratch(), _scratch()
    a1 = Instruction(_IT.ALLOC, node=0, allocation=src, persistent=False)
    a2 = Instruction(_IT.ALLOC, node=0, allocation=dst, persistent=False)
    box = Box((0,), (8,))
    c1 = Instruction(_IT.COPY, node=0, src_alloc=src, dst_alloc=dst,
                     copy_box=box, name="c1")
    c2 = Instruction(_IT.COPY, node=0, src_alloc=src, dst_alloc=dst,
                     copy_box=box, name="c2")
    for c in (c1, c2):
        c.add_dependency(a1, DepKind.TRUE)
        c.add_dependency(a2, DepKind.TRUE)
    if ordered:
        c2.add_dependency(c1, DepKind.OUTPUT)
    f1 = Instruction(_IT.FREE, node=0, allocation=src)
    f2 = Instruction(_IT.FREE, node=0, allocation=dst)
    for f in (f1, f2):
        f.add_dependency(c1, DepKind.ANTI)
        f.add_dependency(c2, DepKind.ANTI)
    return [a1, a2, c1, c2, f1, f2], (c1, c2)


def test_unordered_writers_race():
    instrs, (c1, c2) = _copy_graph(ordered=False)
    rep = verify_graph([instrs])
    kinds = {i.kind for i in rep.issues}
    assert kinds == {"race"}, rep.issues
    assert {c1.iid, c2.iid} <= set(rep.issues[0].instrs)
    # the same graph with the WAW edge present is clean
    instrs, _ = _copy_graph(ordered=True)
    assert verify_graph([instrs]).ok


def test_use_after_free_and_double_free():
    instrs, _ = _copy_graph(ordered=True)
    a1, a2, c1, c2, f1, f2 = instrs
    late = Instruction(_IT.COPY, node=0, src_alloc=c1.src_alloc,
                       dst_alloc=c1.dst_alloc, copy_box=Box((0,), (8,)))
    late.add_dependency(f2, DepKind.SYNC)
    dup = Instruction(_IT.FREE, node=0, allocation=f1.allocation)
    dup.add_dependency(f1, DepKind.SYNC)
    rep = verify_graph([instrs + [late, dup]])
    kinds = sorted(i.kind for i in rep.issues)
    details = " ".join(i.detail for i in rep.issues)
    assert "use-after-free" in details and "double-free" in details, rep.issues
    assert all(k == "lifetime" for k in kinds)


def test_scratch_leak_and_free_of_unallocated():
    instrs, _ = _copy_graph(ordered=True)
    del instrs[-1]                              # dst FREE gone: leak
    stray = Instruction(_IT.FREE, node=0, allocation=_scratch(lo=16, hi=24))
    rep = verify_graph([instrs + [stray]])
    details = " ".join(i.detail for i in rep.issues)
    assert "never freed" in details and "never-allocated" in details, rep.issues


def test_dependency_cycle_is_deadlock():
    instrs, (c1, c2) = _copy_graph(ordered=True)
    c1.add_dependency(c2, DepKind.SYNC)         # c2 already depends on c1
    rep = verify_graph([instrs])
    dead = [i for i in rep.issues if i.kind == "deadlock"]
    assert dead and {c1.iid, c2.iid} <= set(dead[0].instrs), rep.issues


def test_budget_replay_mismatch():
    instrs, _ = _copy_graph(ordered=True)
    nbytes = instrs[0].allocation.nbytes()
    # the honest peak (both scratches live at once) passes ...
    assert verify_graph([instrs], peaks=[{device_memory(0): 2 * nbytes}]).ok
    # ... an inflated promise is a replay mismatch
    rep = verify_graph([instrs], peaks=[{device_memory(0): 3 * nbytes}])
    assert not rep.ok
    assert rep.issues[0].kind == "budget"
    assert "peak replay mismatch" in rep.issues[0].detail


def test_orphan_receive_and_missing_pilot():
    a = _scratch(bid=7)
    al = Instruction(_IT.ALLOC, node=1, allocation=a, persistent=True)
    from repro.core.region import Region
    recv = Instruction(_IT.RECEIVE, node=1, transfer_id=(9, 7),
                       recv_region=Region.from_box(Box((0,), (8,))),
                       recv_alloc=a)
    recv.add_dependency(al, DepKind.TRUE)
    rep = verify_graph([[], [al, recv]])
    assert any(i.kind == "comm" and "orphan receive" in i.detail
               for i in rep.issues), rep.issues
    # now give it a send, but never post the pilot
    send = Instruction(_IT.SEND, node=0, dest=1, transfer_id=(9, 7),
                       msg_id=0, send_box=Box((0,), (8,)), recv_alloc=a)
    rep = verify_graph([[send], [al, recv]])
    assert any(i.kind == "comm" and "pilot" in i.detail
               for i in rep.issues), rep.issues


def test_verification_error_names_instructions():
    instrs, (c1, c2) = _copy_graph(ordered=False)
    with pytest.raises(VerificationError) as exc:
        verify_graph([instrs]).check()
    msg = str(exc.value)
    assert f"I{c1.iid}" in msg and f"I{c2.iid}" in msg
    assert "missing happens-before edge" in msg


# --------------------------------------------------------------------------
# true negatives: the whole corpus verifies clean
# --------------------------------------------------------------------------
def test_corpus_static_clean():
    for _name, builder in CORPUS:
        for nodes, devs in GRIDS:
            for ren in (False, True):
                ni, pi, vb, pk = _lower(builder(), nodes, devs, renaming=ren)
                rep = verify_graph(ni, pilots=pi, budgets=vb, peaks=pk)
                assert rep.ok, (_name, nodes, devs, ren, rep.issues[:5])
                assert rep.pairs_checked > 0


def test_collective_corpus_static_clean():
    for nodes, devs in [(2, 2), (3, 1)]:
        for ren in (False, True):
            ni, pi, vb, pk = _lower(_wave_tdag(), nodes, devs, renaming=ren,
                                    collectives=True)
            assert any(i.itype is _IT.COLL_SEND for s in ni for i in s)
            rep = verify_graph(ni, pilots=pi, budgets=vb, peaks=pk)
            assert rep.ok, (nodes, devs, ren, rep.issues[:5])


def test_halo_corpus_static_clean():
    for nodes, devs in [(2, 2), (3, 1)]:
        ni, pi, vb, pk = _lower(_halo_tdag(), nodes, devs)
        assert any(i.itype is _IT.SEND for s in ni for i in s)
        rep = verify_graph(ni, pilots=pi, budgets=vb, peaks=pk)
        assert rep.ok, (nodes, devs, rep.issues[:5])


def test_budgeted_spill_static_clean():
    """Half-working-set device budget: the spill/reload traffic and its
    eager-reuse ordering verify clean, budget replay included."""
    for ren in (False, True):
        _ni, _pi, _vb, pk = _lower(_wave_tdag(), 1, 1, renaming=ren)
        hwm = pk[0].get(device_memory(0), 0)
        assert hwm > 0
        budgets = {device_memory(0): max(hwm // 2, 512)}
        ni, pi, vb, pk = _lower(_wave_tdag(), 1, 1, renaming=ren,
                                budgets=budgets)
        assert any(i.itype in (_IT.SPILL, _IT.RELOAD) for s in ni for i in s)
        rep = verify_graph(ni, pilots=pi, budgets=vb, peaks=pk)
        assert rep.ok, (ren, rep.issues[:5])


# --------------------------------------------------------------------------
# true negatives: end to end under Runtime(verify=...)
# --------------------------------------------------------------------------
def _wave_program(q, steps=4):
    rng = np.random.default_rng(11)
    u0 = q.buffer((N,), init=rng.normal(size=N), name="u0")
    u1 = q.buffer((N,), init=np.zeros(N), name="u1")
    E = q.buffer((1,), init=np.zeros(1), name="E")
    cur, nxt = u0, u1
    for s in range(steps):
        def step(chunk, uc, un, _s=s):
            ua = uc.get(Box((0,), (N,)))
            lo, hi = chunk.min[0], chunk.max[0]
            lap = np.roll(ua, 1) + np.roll(ua, -1) - 2.0 * ua
            un.set(chunk, (ua + 0.1 * lap + 0.01 * _s)[lo:hi])

        q.submit(f"step{s}", (N,), [read(cur, all_range()),
                                    write(nxt, one_to_one())], step)

        def esum(chunk, un, red):
            red.contribute(un.get(chunk))

        q.submit(f"E{s}", (N,), [read(nxt, one_to_one()),
                                 reduction(E, "sum")], esum)
        cur, nxt = nxt, cur
    return q.gather(cur)


def test_runtime_end_to_end_clean():
    """verify='final' and the concurrent 'window' mode pass on every grid;
    sync() would raise VerificationError otherwise."""
    for nodes, devs in GRIDS:
        for mode, ren in (("final", False), ("window", True)):
            with Runtime(nodes, devs, renaming=ren, verify=mode,
                         issue_width=8 if ren else None,
                         max_inflight_windows=4 if ren else None) as q:
                _wave_program(q)
                q.sync()
                assert q.warnings == [], q.warnings


def test_runtime_chaos_clean():
    """Chaos transport faults (drops/dups/delays + retries) must not change
    the lowered schedule's invariants."""
    for seed in (5, 7):
        plan = FaultPlan(seed=seed, drop=0.4, duplicate=0.2, delay=0.2)
        with Runtime(2, 2, fault_plan=plan, verify="final") as q:
            _wave_program(q)
            q.sync()


def test_runtime_budget_spill_clean():
    with Runtime(1, 1) as probe:
        _wave_program(probe)
        probe.sync()
        hwm = max(probe.memory_report()[0]["real_peak"].values())
    for ren in (False, True):
        with Runtime(1, 1, device_memory_budget=max(hwm // 2, 1024),
                     renaming=ren, verify="final") as q:
            _wave_program(q)
            q.sync()


def test_window_mode_emits_metrics():
    with Runtime(1, 1, verify="window") as q:
        _wave_program(q)
        q.sync()
        snap = q.metrics_registry.snapshot()
    hist = snap.get("histograms", {})
    assert "verify.window_us" in hist, sorted(hist)
    assert snap.get("counters", {}).get("verify.windows", 0) > 0


def test_serving_replay_verifies_clean():
    """Memo-replay clone windows (incl. cross-window re-anchored deps and
    pipelined depth >= 2) pass verification after drain."""
    for depth in (1, 3):
        with ServingRuntime(1, 1, max_inflight_windows=depth,
                            renaming=depth > 1, verify="final") as srv:
            t = srv.tenant("t0")
            u = t.buffer((N,), init=np.arange(N, dtype=float), name="u")
            for _w in range(8):
                def bump(chunk, uv):
                    uv.set(chunk, uv.get(chunk) + 1.0)

                t.submit("bump", (N,), [read_write(u, one_to_one())], bump)
                t.run()
            t.drain()
            rep = srv.verify_now()
            assert rep.ok and rep.instructions > 0
            assert srv.memo_stats()["hits"] > 0   # replays really happened
            out = t.gather(u)
        np.testing.assert_allclose(out, np.arange(N, dtype=float) + 8.0)


# --------------------------------------------------------------------------
# mutation self-test: the sanitizer is not vacuous
# --------------------------------------------------------------------------
def test_mutation_campaign():
    """>= 200 single-defect mutants over the corpus: >= 95% must be detected
    AND attributed (an issue names a mutated instruction), and every
    mutation operator must have fired."""
    configs = []
    for name, builder in CORPUS:
        grids = GRIDS if name != "wave" else [(2, 2), (3, 1)]
        per = 13 if name != "wave" else 8
        for nodes, devs in grids:
            for ren in (False, True):
                configs.append((f"{name}-{nodes}x{devs}-r{int(ren)}", per,
                                lambda b=builder, n=nodes, d=devs, r=ren:
                                _lower(b(), n, d, renaming=r)))
    for nodes, devs in [(2, 2), (3, 1)]:
        configs.append((f"coll-{nodes}x{devs}", 8,
                        lambda n=nodes, d=devs:
                        _lower(_wave_tdag(), n, d, collectives=True)))

    total = detected = attributed = 0
    ops: dict[str, int] = {}
    miss_log = []
    for k, (tag, per, build) in enumerate(configs):
        res = run_mutation_campaign(build, mutants=per, seed=1000 + 17 * k)
        assert res.skipped == 0, tag
        total += res.total
        detected += res.detected
        attributed += res.attributed
        for op, (t_, _a) in res.by_op().items():
            ops[op] = ops.get(op, 0) + t_
        miss_log += [f"{tag}: {m.mutation.op} {m.mutation.detail[:90]} -> "
                     f"{[str(i)[:90] for i in m.issues[:2]]}"
                     for m in res.misses()]
    assert total >= 200, total
    assert detected / total >= 0.95, (detected, total, miss_log[:10])
    assert attributed / total >= 0.95, (attributed, total, miss_log[:10])
    fired = set(ops)
    expect = {"drop-edge", "retarget-edge", "cycle-edge", "drop-free",
              "double-free", "drop-alloc", "drop-frag", "retarget-send",
              "drop-pilot"}
    assert expect <= fired, sorted(expect - fired)
