"""Fault-injection chaos layer + resilient transport tests (DESIGN.md §10).

Fast (unmarked) tests cover the deterministic FaultPlan, the ack/retransmit/
backoff transport, duplicate suppression, stale-epoch tombstones, crash
attribution, supervised elastic restart and shutdown leak accounting.

``pytest -m chaos`` additionally runs the seeded soak matrix: the three
paper applications under randomized drop/delay/duplicate/reorder/pilot-loss
schedules across grids, asserting bit-identical results vs the fault-free
oracle with retransmits accounted in ``comm_stats``.
"""

import time

import numpy as np
import pytest

from repro.core import (Box, ExecutionAborted, FaultPlan, Runtime, all_range,
                        neighborhood, one_to_one, read, read_write, reduction,
                        write)
from repro.core.allocation import Allocation, PINNED_HOST
from repro.core.backend import WorkItem
from repro.core.communicator import Communicator, Payload, ReceiveArbiter
from repro.core.executor import Executor
from repro.core.faults import (InjectedCrash, NodeFailure, TransportError,
                               run_with_restarts)
from repro.core.instruction_graph import Instruction, InstructionType
from repro.core.region import Region


# -- FaultPlan determinism ----------------------------------------------------
def test_fault_plan_replay_determinism():
    """Same seed => identical per-message decisions; different seed differs
    somewhere.  Decisions hash (seed, tid, msg, attempt), never live state."""
    keys = [((t, b), m, a) for t in range(8) for b in range(2)
            for m in range(4) for a in (1, 2)]
    p1 = FaultPlan(seed=42, drop=0.3, delay=0.3, duplicate=0.3, reorder=0.2)
    p2 = FaultPlan(seed=42, drop=0.3, delay=0.3, duplicate=0.3, reorder=0.2)
    p3 = FaultPlan(seed=43, drop=0.3, delay=0.3, duplicate=0.3, reorder=0.2)
    f1 = [p1.payload_fate(t, m, a) for t, m, a in keys]
    f2 = [p2.payload_fate(t, m, a) for t, m, a in keys]
    f3 = [p3.payload_fate(t, m, a) for t, m, a in keys]
    assert f1 == f2
    assert f1 != f3
    assert any(f.drop for f in f1) and any(f.duplicate for f in f1)
    # attempts re-roll: a message is never dropped on EVERY attempt
    for t, m, _ in keys:
        assert not all(p1.payload_fate(t, m, a).drop for a in range(1, 30))


def test_fault_plan_survivors_clears_crash_only():
    p = FaultPlan(seed=1, drop=0.1, crash={1: 5}, slow={0: 0.01})
    s = p.survivors()
    assert s.crash == {} and s.drop == 0.1 and s.slow == {0: 0.01}
    assert p.crash_point(1) == 5 and s.crash_point(1) is None


# -- reliable transport units -------------------------------------------------
def _recv_setup(comm, tid, n=4):
    store = {}
    box = Box((0,), (n,))
    alloc = Allocation(mid=PINNED_HOST, bid=0, box=box)
    store[alloc.aid] = np.full(n, -1.0)
    arb = ReceiveArbiter(0, comm, store)
    recv = Instruction(InstructionType.RECEIVE, node=0, transfer_id=tid,
                       recv_region=Region.from_box(box), recv_alloc=alloc)
    recv.state = "issued"
    arb.begin(recv)
    return store, alloc, arb, recv, box


def test_retransmit_backoff_then_transport_error():
    """A send that is never acked is retransmitted with exponential backoff
    and reported as a TransportError after ``max_retries`` attempts."""
    plan = FaultPlan(seed=0, drop=1.0)      # every attempt dropped
    comm = Communicator(2, fault_plan=plan, retransmit_timeout=0.002,
                        max_retries=3)
    comm.isend(0, Payload(1, 0, (1, 0), Box((0,), (1,)), np.ones(1)))
    assert comm.unacked(1) == 1
    failures = []
    deadline = time.monotonic() + 5.0
    while not failures and time.monotonic() < deadline:
        time.sleep(0.002)
        failures = comm.pump(1)
    assert len(failures) == 1
    assert isinstance(failures[0], TransportError)
    assert "unacked after" in str(failures[0]) and "tid=(1, 0)" in str(failures[0])
    assert comm.unacked(1) == 0             # entry removed after giving up
    assert comm.retries == 3                # one per allowed retry
    assert comm.fault_counts["drop"] == 4   # initial + 3 retransmits
    # logical accounting never includes recovery traffic
    assert comm.num_messages == 1


def test_drop_recovered_by_retransmit_bit_identical():
    """A dropped payload is retransmitted until delivered; the landed bytes
    match, and the retry is accounted separately from logical traffic."""
    tid = (2, 0)
    # pick a seed whose schedule drops attempt 1 and delivers attempt 2
    seed = next(s for s in range(500)
                if FaultPlan(seed=s, drop=0.5).payload_fate(tid, 0, 1).drop
                and not FaultPlan(seed=s, drop=0.5).payload_fate(tid, 0, 2).drop)
    comm = Communicator(2, fault_plan=FaultPlan(seed=seed, drop=0.5),
                        retransmit_timeout=0.002)
    store, alloc, arb, recv, box = _recv_setup(comm, tid)
    data = np.arange(4.0)
    comm.isend(0, Payload(1, 0, tid, box, data))
    done = []
    deadline = time.monotonic() + 5.0
    while recv not in done and time.monotonic() < deadline:
        time.sleep(0.001)
        comm.pump(1)
        arb.step(done)
    assert recv in done
    np.testing.assert_array_equal(store[alloc.aid], data)
    assert comm.fault_counts["drop"] >= 1 and comm.retries >= 1
    assert comm.num_messages == 1 and comm.retry_bytes >= data.nbytes
    comm.pump(1)                            # ack drains the retransmit queue
    assert comm.unacked(1) == 0


def test_duplicate_delivery_suppressed_and_acked():
    """An injected duplicate lands exactly once; every copy is acked so the
    sender's retransmit entry clears either way."""
    comm = Communicator(2, fault_plan=FaultPlan(seed=0, duplicate=1.0))
    store, alloc, arb, recv, box = _recv_setup(comm, (3, 0))
    comm.isend(0, Payload(1, 0, (3, 0), box, np.arange(4.0)))
    assert len(comm.payload_box[0]) == 2    # duplicate injected on the wire
    done = []
    arb.step(done)
    assert recv in done
    np.testing.assert_array_equal(store[alloc.aid], np.arange(4.0))
    assert arb.dups_suppressed == 1
    assert comm.acks == 2                   # both copies acked
    comm.pump(1)
    assert comm.unacked(1) == 0


def test_poisoned_tids_reject_late_payloads():
    """After an epoch abort, retransmits for tombstoned transfers never land
    (their allocations may be gone) — but are still acked."""
    comm = Communicator(2)
    store, alloc, arb, recv, box = _recv_setup(comm, (4, 0))
    assert arb.poison("test abort") == 1
    assert not arb.has_pending()
    comm.isend(0, Payload(1, 0, (4, 0), box, np.arange(4.0)))
    done = []
    arb.step(done)
    assert done == [] and arb.stale_rejected == 1
    np.testing.assert_array_equal(store[alloc.aid], np.full(4, -1.0))
    assert comm.acks == 1                   # the wire did deliver it
    comm.pump(1)
    assert comm.unacked(1) == 0


def test_run_with_restarts_bounded():
    calls = []

    def attempt(restarts):
        calls.append(restarts)
        if len(calls) < 3:
            raise RuntimeError(f"boom {len(calls)}")
        return "ok"

    seen = []
    out, restarts = run_with_restarts(attempt, lambda e, r: seen.append(str(e)),
                                      max_restarts=3)
    assert out == "ok" and restarts == 2 and calls == [0, 1, 2]
    assert seen == ["boom 1", "boom 2"]
    with pytest.raises(RuntimeError, match="always"):
        run_with_restarts(lambda r: (_ for _ in ()).throw(RuntimeError("always")),
                          lambda e, r: None, max_restarts=1)


# -- programs under test ------------------------------------------------------
def nbody_oracle(P0, V0, steps, dt=0.01, M=1.0):
    P, V = P0.copy(), V0.copy()
    for _ in range(steps):
        d = P[None, :, :] - P[:, None, :]
        r2 = (d * d).sum(-1) + 1e-3
        F = (d / r2[..., None] ** 1.5).sum(1)
        V = V + M * F * dt
        P = P + V * dt
    return P, V


def _nbody_parts(N=32, steps=3, dt=0.01, M=1.0):
    rng = np.random.default_rng(7)
    P0 = rng.normal(size=(N, 3))
    V0 = rng.normal(size=(N, 3)) * 0.1

    def build(rt, init):
        snap = init if init is not None else {"P": P0, "V": V0}
        return {"P": rt.buffer((N, 3), init=snap["P"], name="P"),
                "V": rt.buffer((N, 3), init=snap["V"], name="V")}

    def step(rt, bufs, i):
        P, V = bufs["P"], bufs["V"]

        def timestep(chunk, p_view, v_view):
            Pa = p_view.get(Box((0, 0), (N, 3)))
            d = Pa[None, :, :] - Pa[chunk.min[0]:chunk.max[0], None, :]
            r2 = (d * d).sum(-1) + 1e-3
            F = (d / r2[..., None] ** 1.5).sum(1)
            v_view.set(chunk, v_view.get(chunk) + M * F * dt)

        def update(chunk, v_view, p_view):
            p_view.set(chunk, p_view.get(chunk) + v_view.get(chunk) * dt)

        rt.submit(f"timestep{i}", (N, 3),
                  [read(P, all_range()), read_write(V, one_to_one())], timestep)
        rt.submit(f"update{i}", (N, 3),
                  [read(V, one_to_one()), read_write(P, one_to_one())], update)

    return build, step, P0, V0


def run_nbody(nodes, devs, steps=3, **rt_kwargs):
    build, step, P0, V0 = _nbody_parts(steps=steps)
    with Runtime(num_nodes=nodes, devices_per_node=devs, **rt_kwargs) as rt:
        bufs = build(rt, None)
        for i in range(steps):
            step(rt, bufs, i)
        out = {k: rt.gather(b) for k, b in sorted(bufs.items())}
        stats = rt.comm_stats()
        assert rt.warnings == [], rt.warnings
    return out, stats


def run_wavesim(nodes, devs, H=16, W=12, steps=3, **rt_kwargs):
    rng = np.random.default_rng(3)
    u0 = np.zeros((H, W))
    u1 = rng.normal(size=(H, W)) * 0.01
    u1[0, :] = u1[-1, :] = u1[:, 0] = u1[:, -1] = 0.0
    c = 0.25

    def step_kernel(chunk, um_v, u_v, un_v):
        lo, hi = chunk.min[0], chunk.max[0]
        ext = Box((max(0, lo - 1), 0), (min(H, hi + 1), W))
        u = u_v.get(ext)
        um = um_v.get(chunk)
        pad = lo - ext.min[0]
        out = np.empty((hi - lo, W))
        for r in range(hi - lo):
            g = r + pad
            gi = lo + r
            if gi == 0 or gi == H - 1:
                out[r] = 0.0
                continue
            row = u[g]
            lap = (u[g - 1] + u[g + 1] + np.roll(row, 1) + np.roll(row, -1)
                   - 4 * row)
            out[r] = 2 * row - um[r] + c * lap
            out[r, 0] = out[r, -1] = 0.0
        un_v.set(chunk, out)

    with Runtime(num_nodes=nodes, devices_per_node=devs, **rt_kwargs) as rt:
        B = [rt.buffer((H, W), init=u0, name="um"),
             rt.buffer((H, W), init=u1, name="u"),
             rt.buffer((H, W), init=np.zeros((H, W)), name="un")]
        for s in range(steps):
            um, u, un = B[s % 3], B[(s + 1) % 3], B[(s + 2) % 3]
            rt.submit(f"wave{s}", (H, W),
                      [read(um, one_to_one()), read(u, neighborhood((1, 0))),
                       write(un, one_to_one())], step_kernel)
        out = {"u": rt.gather(B[(steps + 1) % 3])}
        stats = rt.comm_stats()
        assert rt.warnings == [], rt.warnings
    return out, stats


def run_allreduce(nodes, devs, n=97, **rt_kwargs):
    rng = np.random.default_rng(23)
    data = rng.normal(size=n) * 10.0 ** rng.integers(-12, 12, size=n)
    vdata = rng.normal(size=(n, 3))
    with Runtime(num_nodes=nodes, devices_per_node=devs, host_threads=2,
                 **rt_kwargs) as rt:
        X = rt.buffer((n,), init=data, name="X")
        E = rt.buffer((1,), init=np.zeros(1), name="E")
        Y = rt.buffer((n, 3), init=vdata, name="Y")
        W = rt.buffer((3,), init=np.zeros(3), name="W")

        def ke(chunk, xv, red):
            red.contribute(xv.get(chunk))

        def kw(chunk, yv, red):
            red.contribute(yv.get(Box((chunk.min[0], 0), (chunk.max[0], 3))))

        rt.submit("e", (n,), [read(X, one_to_one()), reduction(E, "sum")], ke)
        rt.submit("w", (n, 3), [read(Y, one_to_one()), reduction(W, "sum")], kw)
        out = {"E": rt.gather(E), "W": rt.gather(W)}
        stats = rt.comm_stats()
        assert rt.warnings == [], rt.warnings
    return out, stats


PROGRAMS = {"nbody": run_nbody, "wavesim": run_wavesim,
            "allreduce": run_allreduce}
_oracles: dict = {}


def oracle(prog, nodes, devs):
    key = (prog, nodes, devs)
    if key not in _oracles:
        _oracles[key] = PROGRAMS[prog](nodes, devs)[0]
    return _oracles[key]


# -- fault-free invariants ----------------------------------------------------
def test_zero_fault_transport_invariants():
    """On a clean wire every sequenced message is acked exactly once and no
    recovery traffic exists."""
    out, stats = run_nbody(2, 1)
    ref = oracle("nbody", 2, 1)
    for k in out:
        np.testing.assert_array_equal(out[k], ref[k])
    assert stats["retries"] == 0 and stats["retry_bytes"] == 0
    assert stats["dups_suppressed"] == 0 and stats["stale_rejected"] == 0
    assert stats["aborts"] == 0
    assert all(v == 0 for v in stats["faults_injected"].values())
    assert stats["messages"] > 0 and stats["acks"] == stats["messages"]


def test_unreliable_opt_out_still_correct():
    """``reliable=False`` retains the historical fire-and-forget wire."""
    out, stats = run_nbody(2, 1, reliable=False)
    ref = oracle("nbody", 2, 1)
    for k in out:
        np.testing.assert_array_equal(out[k], ref[k])
    assert stats["acks"] == 0 and stats["retries"] == 0


def test_wire_faults_require_reliable_transport():
    with pytest.raises(ValueError, match="reliable"):
        Communicator(2, reliable=False, fault_plan=FaultPlan(drop=0.1))


def test_fault_smoke_bit_identical():
    """One seeded chaos schedule in the default (tier-1) suite: results are
    bit-identical to the oracle and retransmits are accounted."""
    plan = FaultPlan(seed=5, drop=0.08, duplicate=0.08, delay=0.08,
                     delay_s=0.004, pilot_drop=0.2)
    out, stats = run_wavesim(2, 2, fault_plan=plan, retransmit_timeout=0.01)
    ref = oracle("wavesim", 2, 2)
    np.testing.assert_array_equal(out["u"], ref["u"])
    injected = stats["faults_injected"]
    assert sum(injected.values()) > 0, injected
    assert stats["retries"] >= injected["drop"]
    assert stats["acks"] >= stats["messages"]


# -- crash attribution + watchdog ---------------------------------------------
def test_crashed_rank_attributed_quickly():
    """A silently fail-stopped rank is named by peers within ~2s: the
    survivor's watchdog reports the stuck instruction and the dead peer, and
    ``sync`` aggregates every failed rank into one diagnosable error."""
    plan = FaultPlan(crash={1: 8})
    rt = Runtime(num_nodes=2, devices_per_node=1, fault_plan=plan,
                 watchdog_timeout=0.3)
    try:
        H, W = 12, 8
        u = rt.buffer((H, W), init=np.ones((H, W)), name="u")
        v = rt.buffer((H, W), init=np.zeros((H, W)), name="v")

        def k(chunk, uv, vv):
            lo, hi = chunk.min[0], chunk.max[0]
            ext = Box((max(0, lo - 1), 0), (min(H, hi + 1), W))
            vv.set(chunk, uv.get(ext)[lo - ext.min[0]:lo - ext.min[0] + hi - lo])

        for s in range(4):
            a, b = (u, v) if s % 2 == 0 else (v, u)
            rt.submit(f"k{s}", (H, W),
                      [read(a, neighborhood((1, 0))), write(b, one_to_one())], k)
        t0 = time.monotonic()
        with pytest.raises(ExecutionAborted) as ei:
            rt.sync(timeout=30.0)
        elapsed = time.monotonic() - t0
    finally:
        rt.shutdown()
    assert elapsed < 2.0, f"attribution took {elapsed:.2f}s"
    msg = str(ei.value)
    assert "N1" in msg and "InjectedCrash" in msg
    failures = dict(ei.value.failures)
    assert isinstance(failures[1], InjectedCrash)
    # the survivor either saw the watchdog fire (naming the dead peer) or
    # was healthy enough to finish — if it failed, the error is attributed
    if 0 in failures:
        assert isinstance(failures[0], NodeFailure)
        assert 1 in failures[0].dead_peers
    assert rt.executors[1].crashed


def test_watchdog_clean_run_never_fires():
    out, stats = run_nbody(2, 1, watchdog_timeout=5.0)
    ref = oracle("nbody", 2, 1)
    for k in out:
        np.testing.assert_array_equal(out[k], ref[k])
    assert stats["aborts"] == 0


def test_slow_rank_completes_correctly():
    """A straggler rank (injected per-kernel sleep) delays but never corrupts."""
    plan = FaultPlan(slow={1: 0.002})
    out, _ = run_nbody(2, 1, fault_plan=plan)
    ref = oracle("nbody", 2, 1)
    for k in out:
        np.testing.assert_array_equal(out[k], ref[k])


# -- supervised elastic restart ----------------------------------------------
def test_run_supervised_no_faults():
    build, step, P0, V0 = _nbody_parts(steps=4)
    res = Runtime.run_supervised(build, step, steps=4, num_nodes=2,
                                 checkpoint_every=2, watchdog_timeout=None)
    Pe, Ve = nbody_oracle(P0, V0, 4)
    assert res.restarts == 0 and res.world == 2 and res.steps == 4
    np.testing.assert_array_equal(res.results["P"], Pe)
    np.testing.assert_array_equal(res.results["V"], Ve)


def test_run_supervised_crash_restart_bit_identical():
    """A rank crash mid-run triggers teardown, elastic shrink and resubmission
    from the last snapshot; the final buffers are bit-identical to the
    fault-free oracle and restarts stay bounded."""
    build, step, P0, V0 = _nbody_parts(steps=4)
    plan = FaultPlan(crash={1: 30})
    res = Runtime.run_supervised(build, step, steps=4, num_nodes=2,
                                 checkpoint_every=1, fault_plan=plan,
                                 watchdog_timeout=0.3, sync_timeout=30.0)
    Pe, Ve = nbody_oracle(P0, V0, 4)
    assert res.restarts == 1, res
    assert res.world == 1                   # shrank by the lost rank
    np.testing.assert_array_equal(res.results["P"], Pe)
    np.testing.assert_array_equal(res.results["V"], Ve)


def test_run_supervised_exhausts_restarts():
    def build(rt, init):
        return {"B": rt.buffer((4,), init=np.zeros(4), name="B")}

    def step(rt, bufs, i):
        def bad(chunk, v):
            raise RuntimeError("injected permanent failure")
        rt.submit(f"s{i}", (4,), [read_write(bufs["B"], one_to_one())], bad)

    with pytest.raises(ExecutionAborted, match="permanent failure"):
        Runtime.run_supervised(build, step, steps=1, num_nodes=1,
                               max_restarts=1, watchdog_timeout=None)


# -- shutdown hygiene ---------------------------------------------------------
def test_shutdown_reports_leaked_threads():
    """A backend lane wedged in user code cannot be joined: shutdown counts
    it, warns, and still tears the rest down instead of hanging."""
    import threading
    release = threading.Event()
    comm = Communicator(1)
    ex = Executor(0, 1, comm, host_threads=2)
    ex.backend.host_pool.submit(WorkItem(fn=lambda tag: release.wait(30.0)))
    time.sleep(0.05)                        # let a lane pick the item up
    ex.errors.append(RuntimeError("injected failure"))
    try:
        leaked = ex.shutdown()
        assert leaked >= 1
        assert ex.leaked_threads == leaked
        assert any("leak" in w or "join" in w for w in ex.warnings), ex.warnings
    finally:
        release.set()


def test_clean_shutdown_thread_report():
    with Runtime(2, 1) as rt:
        B = rt.buffer((8,), init=np.zeros(8), name="B")
        rt.submit("k", (8,), [read_write(B, one_to_one())],
                  lambda c, v: v.set(c, v.get(c) + 1))
        rt.sync()
    rep = rt.thread_report()
    assert rep["total_leaked"] == 0 and rep["warnings"] == []
    assert all(r["leaked_threads"] == 0 for r in rt.memory_report())


# -- chaos soak matrix (pytest -m chaos) --------------------------------------
CHAOS_GRIDS = [(2, 2), (3, 1)]
CHAOS_SEEDS_PER_CELL = 4


def _chaos_cases():
    cases = []
    for pi, prog in enumerate(sorted(PROGRAMS)):
        for gi, grid in enumerate(CHAOS_GRIDS):
            base = (pi * len(CHAOS_GRIDS) + gi) * CHAOS_SEEDS_PER_CELL
            for s in range(CHAOS_SEEDS_PER_CELL):
                cases.append((prog, grid, base + s))
    return cases       # 3 progs x 2 grids x 4 = 24 distinct seeds


@pytest.mark.chaos
@pytest.mark.parametrize("prog,grid,seed", _chaos_cases())
def test_chaos_determinism(prog, grid, seed):
    """Under a seeded non-crash fault schedule the program's results are
    bit-identical to the fault-free oracle, and the recovery traffic is
    visible in ``comm_stats`` without polluting logical counters."""
    nodes, devs = grid
    plan = FaultPlan(seed=seed, drop=0.05, duplicate=0.05, delay=0.05,
                     delay_s=0.004, reorder=0.05, reorder_s=0.001,
                     pilot_drop=0.15)
    out, stats = PROGRAMS[prog](nodes, devs, fault_plan=plan,
                                retransmit_timeout=0.01)
    ref = oracle(prog, nodes, devs)
    for k in ref:
        np.testing.assert_array_equal(out[k], ref[k], err_msg=f"{prog} {k}")
    injected = stats["faults_injected"]
    # every dropped attempt forces a retransmit; dups are suppressed on land
    assert stats["retries"] >= injected["drop"]
    assert stats["acks"] >= stats["messages"]
    if injected["dup"]:
        assert stats["dups_suppressed"] > 0
    # logical accounting must match the fault-free run exactly
    ref_stats = PROGRAMS[prog](nodes, devs)[1]
    assert stats["messages"] == ref_stats["messages"]
    assert stats["bytes"] == ref_stats["bytes"]


@pytest.mark.chaos
@pytest.mark.parametrize("seed", range(100, 104))
def test_chaos_crash_plus_wire_faults_supervised(seed):
    """Crash + wire faults together: supervised execution still converges to
    the bit-identical result with bounded restarts."""
    build, step, P0, V0 = _nbody_parts(steps=4)
    plan = FaultPlan(seed=seed, drop=0.04, duplicate=0.04, delay=0.04,
                     delay_s=0.003, crash={1: 20 + 7 * (seed % 4)})
    res = Runtime.run_supervised(build, step, steps=4, num_nodes=2,
                                 checkpoint_every=1, fault_plan=plan,
                                 watchdog_timeout=0.4, sync_timeout=30.0,
                                 retransmit_timeout=0.01)
    Pe, Ve = nbody_oracle(P0, V0, 4)
    assert res.restarts <= 3
    np.testing.assert_array_equal(res.results["P"], Pe)
    np.testing.assert_array_equal(res.results["V"], Ve)
