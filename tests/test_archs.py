"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs; plus a decode
step for decode-capable archs.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHITECTURES, get_config
from repro.launch.inputs import train_batch
from repro.models import build_model

B, S = 2, 32


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch, reduced=True)
            m = build_model(cfg)
            params = m.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, m, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_full_config_matches_assignment(arch):
    """The exact published numbers from the assignment block."""
    cfg = get_config(arch)
    expected = {
        "starcoder2_3b": (30, 3072, 24, 2, 12288, 49152),
        "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
        "h2o_danube_1_8b": (24, 2560, 32, 8, 6912, 32000),
        "qwen2_1_5b": (28, 1536, 12, 2, 8960, 151936),
        "granite_moe_1b_a400m": (24, 1024, 16, 8, 512, 49155),
        "granite_moe_3b_a800m": (32, 1536, 24, 8, 512, 49155),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
        "mamba2_370m": (48, 1024, 0, 0, 0, 50280),
        "whisper_tiny": (4, 384, 6, 6, 1536, 51865),
        "internvl2_26b": (48, 6144, 48, 8, 16384, 92553),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, f"{arch}: {got} != {expected}"


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_smoke_forward(arch, arch_setup):
    cfg, m, params = arch_setup(arch)
    batch = train_batch(cfg, B, S)
    if cfg.family in ("audio", "vlm"):
        logits, _ = m.forward(params, batch)
    else:
        logits, _ = m.forward(params, batch["tokens"])
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch} produced non-finite logits"


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_smoke_train_step(arch, arch_setup):
    """One SGD step: loss and gradients finite, loss decreases on repeat."""
    cfg, m, params = arch_setup(arch)
    batch = train_batch(cfg, B, S)
    loss, grads = jax.value_and_grad(m.loss)(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all()), f"{arch} non-finite grad"
    p2 = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
    loss2 = m.loss(p2, batch)
    assert float(loss2) < float(loss), \
        f"{arch}: loss did not decrease ({loss} -> {loss2})"


DECODE_ARCHS = [a for a in ARCHITECTURES if a not in ("whisper_tiny",
                                                      "internvl2_26b")]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_smoke_decode(arch, arch_setup):
    cfg, m, params = arch_setup(arch)
    ids = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab_size)
    logits, cache = m.prefill(params, ids, max_len=16)
    assert logits.shape == (B, cfg.vocab_size)
    logits, cache = m.decode_step(params, cache,
                                  jnp.argmax(logits, -1)[:, None].astype(jnp.int32))
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


def test_whisper_decode():
    cfg = get_config("whisper_tiny", reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(1),
                               (B, cfg.enc_frames, cfg.d_model))
    enc = m.encode(params, frames)
    cache = m.init_cache(B, 16)
    ids = jnp.zeros((B, 1), jnp.int32)
    logits, cache = m.decode_step(params, cache, ids, enc)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


def test_internvl_decode():
    from repro.models.internvl import D_VIS
    cfg = get_config("internvl2_26b", reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    vis = jax.random.normal(jax.random.PRNGKey(1), (B, cfg.vis_tokens, D_VIS))
    ids = jnp.zeros((B, 4), jnp.int32)
    logits, cache = m.prefill(params, vis, ids, max_len=32)
    logits, cache = m.decode_step(params, cache, jnp.zeros((B, 1), jnp.int32))
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


def test_param_counts_in_expected_range():
    """Full configs should land near their nameplate sizes."""
    expect = {"starcoder2_3b": (2.5e9, 3.5e9),
              "minitron_4b": (3.5e9, 5.0e9),
              "h2o_danube_1_8b": (1.5e9, 2.2e9),
              "qwen2_1_5b": (1.2e9, 2.0e9),
              "mamba2_370m": (0.3e9, 0.5e9),
              "zamba2_7b": (6.0e9, 8.5e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
