"""Property tests for the region algebra against a brute-force bitmap oracle.

Every scheduler layer is built on this algebra, so it must be exact.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.region import Box, Region, RegionMap, split_box

BOUND = 12


def boxes(rank: int):
    def mk(lo_hi):
        lo = tuple(min(a, b) for a, b in lo_hi)
        hi = tuple(max(a, b) for a, b in lo_hi)
        return Box(lo, hi)
    coord = st.integers(0, BOUND)
    return st.lists(st.tuples(coord, coord), min_size=rank, max_size=rank).map(mk)


def regions(rank: int):
    return st.lists(boxes(rank), min_size=0, max_size=4).map(Region)


def bitmap(r: Region, rank: int) -> np.ndarray:
    grid = np.zeros((BOUND,) * rank, dtype=bool)
    for b in r.boxes:
        sl = tuple(slice(max(0, a), min(BOUND, c)) for a, c in zip(b.min, b.max))
        grid[sl] = True
    return grid


@pytest.mark.parametrize("rank", [1, 2, 3])
class TestRegionAlgebra:
    @settings(max_examples=120, deadline=None)
    @given(data=st.data())
    def test_union(self, rank, data):
        a, b = data.draw(regions(rank)), data.draw(regions(rank))
        assert np.array_equal(bitmap(a.union(b), rank),
                              bitmap(a, rank) | bitmap(b, rank))

    @settings(max_examples=120, deadline=None)
    @given(data=st.data())
    def test_intersect(self, rank, data):
        a, b = data.draw(regions(rank)), data.draw(regions(rank))
        assert np.array_equal(bitmap(a.intersect(b), rank),
                              bitmap(a, rank) & bitmap(b, rank))

    @settings(max_examples=120, deadline=None)
    @given(data=st.data())
    def test_difference(self, rank, data):
        a, b = data.draw(regions(rank)), data.draw(regions(rank))
        assert np.array_equal(bitmap(a.difference(b), rank),
                              bitmap(a, rank) & ~bitmap(b, rank))

    @settings(max_examples=80, deadline=None)
    @given(data=st.data())
    def test_boxes_disjoint_and_volume(self, rank, data):
        a = data.draw(regions(rank))
        # normalized boxes must be pairwise disjoint
        for i, x in enumerate(a.boxes):
            for y in a.boxes[i + 1:]:
                assert not x.overlaps(y)
        assert a.volume() == int(bitmap(a, rank).sum())

    @settings(max_examples=80, deadline=None)
    @given(data=st.data())
    def test_contains_equiv(self, rank, data):
        a, b = data.draw(regions(rank)), data.draw(regions(rank))
        assert a.contains(b) == bool((bitmap(b, rank) & ~bitmap(a, rank)).sum() == 0)

    @settings(max_examples=80, deadline=None)
    @given(data=st.data())
    def test_eq_is_set_eq(self, rank, data):
        a, b = data.draw(regions(rank)), data.draw(regions(rank))
        assert (a == b) == np.array_equal(bitmap(a, rank), bitmap(b, rank))


@settings(max_examples=100, deadline=None)
@given(data=st.data())
def test_region_map_last_writer_semantics(data):
    """RegionMap.update must behave like painting on a grid."""
    bounds = Box((0, 0), (BOUND, BOUND))
    rm = RegionMap(bounds, default=0)
    grid = np.zeros((BOUND, BOUND), dtype=int)
    for val in range(1, data.draw(st.integers(1, 6)) + 1):
        r = data.draw(regions(2))
        rm.update(r, val)
        grid[bitmap(r, 2)] = val
    for sub, v in rm.query(Region.from_box(bounds)):
        for b in sub.boxes:
            sl = tuple(slice(a, c) for a, c in zip(b.min, b.max))
            assert (grid[sl] == v).all(), f"value mismatch in {b}"
    # disjointness of entries
    seen = Region.empty()
    for r, _ in rm.entries:
        assert not seen.overlaps(r)
        seen = seen.union(r)


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 64), st.integers(1, 16), st.integers(1, 4))
def test_split_box_partition(extent, chunks, gran):
    box = Box((0, 0), (extent, 5))
    parts = split_box(box, chunks, dims=(0,), granularity=(gran,))
    # exact partition
    assert Region(parts) == Region.from_box(box)
    assert sum(p.volume() for p in parts) == box.volume()
    assert len(parts) <= chunks
    # all but the last chunk aligned to granularity
    for p in parts[:-1]:
        assert (p.max[0] - p.min[0]) % gran == 0


def test_split_box_2d():
    box = Box((0, 0), (8, 8))
    parts = split_box(box, 4, dims=(0, 1))
    assert Region(parts) == Region.from_box(box)
    assert len(parts) == 4
