"""Scheduler-memory retirement tests (DESIGN.md §3): TDAG/CDAG prefixes are
retired at horizons in runtime mode, so every graph layer holds O(window)
state on long programs while lifetime counters keep the totals.
"""

import numpy as np

from repro.core import (CommandGraphGenerator, Runtime, TaskGraph,
                        generate_cdag, one_to_one, read, read_write, write)
from repro.core.buffer import VirtualBuffer


def _long_run(steps: int):
    with Runtime(num_nodes=2, devices_per_node=1) as rt:
        A = rt.buffer((64,), init=np.zeros(64), name="A")
        B = rt.buffer((64,), init=np.zeros(64), name="B")
        for s in range(steps):
            def k(chunk, av, bv, s=s):
                bv.set(chunk, bv.get(chunk) + av.get(chunk) + s)
            rt.submit(f"k{s}", (64,), [read(A, one_to_one()),
                                       read_write(B, one_to_one())], k)
        rt.sync()
        tdag_retained = len(rt.tdag.tasks)
        tdag_total = rt.tdag.task_count
        cdag_retained = [len(s.cdag.commands[n]) for s in rt.schedulers
                         for n in range(rt.num_nodes)]
        cdag_total = [sum(s.cdag.emitted_counts) for s in rt.schedulers]
        out = rt.gather(B)
    return tdag_retained, tdag_total, cdag_retained, cdag_total, out


def test_long_run_bounded_tdag_cdag():
    """Retained task/command counts are O(horizon window), independent of
    program length; lifetime counters still see every emission."""
    r60 = _long_run(60)
    r240 = _long_run(240)
    # totals grow with the program ...
    assert r240[1] > r60[1] >= 60
    assert min(r240[3]) > min(r60[3])
    # ... retained state does not
    assert r240[0] <= 32 and r60[0] <= 32
    assert max(r240[2]) <= 32 and max(r60[2]) <= 32
    assert r240[0] <= r60[0] + 4          # O(window), not O(program)
    assert max(r240[2]) <= max(r60[2]) + 4
    # and the computation is still correct
    np.testing.assert_array_equal(
        r240[4], np.full(64, sum(range(240)), dtype=float))


def test_retirement_results_identical():
    """Bit-identical results with the retiring runtime vs a standalone
    unretired TDAG/CDAG replay of the same program."""
    def run(steps=40):
        with Runtime(num_nodes=1, devices_per_node=2) as rt:
            B = rt.buffer((32,), init=np.ones(32), name="B")
            for s in range(steps):
                def k(chunk, bv, s=s):
                    bv.set(chunk, bv.get(chunk) * 1.0001 + s * 1e-6)
                rt.submit(f"s{s}", (32,), [read_write(B, one_to_one())], k)
            return rt.gather(B)

    a, b = run(), run()
    np.testing.assert_array_equal(a, b)


def test_standalone_generators_do_not_retire():
    """Tests and tools that build their own graphs keep full history (the
    retirement is opt-in via the runtime)."""
    tdag = TaskGraph(horizon_step=2)
    B = VirtualBuffer((16,), name="B", initial_value=np.zeros(16))
    for i in range(20):
        tdag.submit(f"k{i}", (16,), [read_write(B, one_to_one())])
    assert len(tdag.tasks) == tdag.task_count > 20     # incl. horizons
    gen = generate_cdag(tdag, 2)
    assert all(len(cmds) == cnt
               for cmds, cnt in zip(gen.commands, gen.emitted_counts))
    assert all(len(cmds) > 20 for cmds in gen.commands)


def test_cdag_retire_mode_trims_and_counts():
    tdag = TaskGraph(horizon_step=2)
    B = VirtualBuffer((16,), name="B", initial_value=np.zeros(16))
    for i in range(20):
        tdag.submit(f"k{i}", (16,), [write(B, one_to_one())])
    gen = CommandGraphGenerator(2, retire_for=0)
    for t in tdag.tasks:
        if t.name == "init":
            continue
        gen.process(t)
    assert all(len(cmds) <= 8 for cmds in gen.commands)
    assert all(c > 20 for c in gen.emitted_counts)
