"""Property tests for the scheduler-lookahead invariants (paper §4.3).

For ANY sequence of tasks with random access patterns:
  * lookahead never allocates MORE than ad-hoc compilation;
  * the executed results are bit-identical with lookahead on/off;
  * every queued command is eventually compiled (no lost work).
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Box, CommandType, IdagGenerator, InstructionType,
                        Region, TaskGraph, fixed, generate_cdag, one_to_one,
                        read, read_write, write)
from repro.core.buffer import VirtualBuffer
from repro.core.lookahead import LookaheadScheduler

N = 32


@st.composite
def task_sequences(draw):
    """A sequence of (read_box, write_box) access patterns on one buffer."""
    n_tasks = draw(st.integers(2, 12))
    out = []
    for _ in range(n_tasks):
        a = draw(st.integers(0, N - 2))
        b = draw(st.integers(a + 1, N))
        c = draw(st.integers(0, N - 2))
        d = draw(st.integers(c + 1, N))
        out.append(((a, b), (c, d)))
    return out


def compile_all(seq, lookahead: bool):
    tdag = TaskGraph()
    B = VirtualBuffer((N,), name="B", initial_value=np.zeros(N))
    for i, ((a, b), (c, d)) in enumerate(seq):
        tdag.submit(f"t{i}", (N,),
                    [read(B, fixed(Box((a,), (b,)))),
                     write(B, fixed(Box((c,), (d,))))])
    gen = generate_cdag(tdag, 1)
    idag = IdagGenerator(0, 1)
    la = LookaheadScheduler(idag, enabled=lookahead)
    n_cmds = 0
    for cmd in gen.commands[0]:
        if cmd.ctype == CommandType.EPOCH and cmd.task is None:
            continue
        la.push(cmd)
        n_cmds += 1
    la.flush()
    kinds = [i.itype for i in idag.instructions]
    return (kinds.count(InstructionType.ALLOC),
            kinds.count(InstructionType.DEVICE_KERNEL), n_cmds, idag)


@settings(max_examples=60, deadline=None)
@given(task_sequences())
def test_lookahead_never_allocates_more(seq):
    a_on, k_on, _, _ = compile_all(seq, lookahead=True)
    a_off, k_off, _, _ = compile_all(seq, lookahead=False)
    assert a_on <= a_off, f"lookahead allocated more: {a_on} > {a_off}"
    # same kernels compiled either way (no lost/duplicated work)
    assert k_on == k_off


@settings(max_examples=30, deadline=None)
@given(task_sequences())
def test_lookahead_topological_and_covering(seq):
    """Lookahead-compiled IDAG still emits in topological order and every
    kernel accessor is backed by a containing allocation."""
    _, _, _, idag = compile_all(seq, lookahead=True)
    pos = {i.iid: k for k, i in enumerate(idag.instructions)}
    for instr in idag.instructions:
        for dep, _ in instr.dependencies:
            assert pos[dep.iid] < pos[instr.iid]
        if instr.itype == InstructionType.DEVICE_KERNEL:
            for bnd in instr.bindings:
                assert bnd.allocation.box.contains(bnd.region.bounding_box())
    # live backing allocations per (buffer, memory) stay pairwise disjoint
    for (bid, mid), allocs in idag._allocs.items():
        live = [a for a in allocs if a.live]
        for i, a in enumerate(live):
            for b in live[i + 1:]:
                assert not a.box.overlaps(b.box), (a, b)


@settings(max_examples=20, deadline=None)
@given(task_sequences())
def test_lookahead_execution_equivalence(seq):
    """End-to-end: results identical with lookahead on and off."""
    from repro.core import Runtime

    def run(lookahead):
        with Runtime(1, 1, lookahead=lookahead) as rt:
            B = rt.buffer((N,), name="B", init=np.zeros(N))
            for i, ((a, b), (c, d)) in enumerate(seq):
                def k(chunk, rv, wv, a=a, b=b, c=c, d=d, i=i):
                    data = rv.get(Box((a,), (b,)))
                    val = float(data.sum()) + i + 1.0
                    wv.set(Box((c,), (d,)), np.full(d - c, val))
                rt.submit(f"t{i}", (N,),
                          [read(B, fixed(Box((a,), (b,)))),
                           write(B, fixed(Box((c,), (d,))))], k)
            return rt.gather(B)

    np.testing.assert_array_equal(run(True), run(False))
