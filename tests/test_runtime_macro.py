"""Macro runtime tests: data determinism, checkpoint atomicity + async save,
IDAG-orchestrated training with prefetch/ckpt overlap, checkpoint/restart
fault tolerance, elastic reshard, serving loop, gradient compression."""

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import Prefetcher, SyntheticLMData
from repro.runtime import ElasticTrainer, ServeLoop, TrainLoop, rebalance_weights


CFG = get_config("qwen2_1_5b", reduced=True)


# -- data pipeline ------------------------------------------------------------
def test_data_deterministic_and_shardable():
    d = SyntheticLMData(CFG, global_batch=8, seq_len=16, seed=3)
    a = d.local_batch(5)
    b = d.local_batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # dp shards are slices of a deterministic stream: different ranks differ
    r0 = d.local_batch(5, dp_rank=0, dp_size=4)
    r1 = d.local_batch(5, dp_rank=1, dp_size=4)
    assert r0["tokens"].shape == (2, 16)
    assert not np.array_equal(r0["tokens"], r1["tokens"])
    assert a["tokens"].max() < CFG.vocab_size


def test_prefetcher_overlap_and_order():
    d = SyntheticLMData(CFG, global_batch=4, seq_len=8)
    pf = Prefetcher(d, start_step=7, depth=2)
    s0, b0 = pf.next()
    s1, b1 = pf.next()
    pf.stop()
    assert (s0, s1) == (7, 8)
    np.testing.assert_array_equal(b0["tokens"], d.local_batch(7)["tokens"])


# -- checkpoint store ------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(10.0), "b": {"c": np.ones((3, 4), np.float32),
                                        "d": np.int32(7)}}
    save_checkpoint(tmp_path, 42, tree, num_shards=2)
    assert latest_step(tmp_path) == 42
    step, out = restore_checkpoint(tmp_path, tree)
    assert step == 42
    np.testing.assert_array_equal(out["a"], tree["a"])
    np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_checkpoint_atomicity(tmp_path):
    """A step dir without its COMMITTED marker must be invisible."""
    tree = {"a": np.arange(4.0)}
    save_checkpoint(tmp_path, 10, tree)
    (tmp_path / "step_000020").mkdir()          # torn save: no marker
    assert latest_step(tmp_path) == 10


def test_checkpoint_manager_async(tmp_path):
    mgr = CheckpointManager(tmp_path, interval=5, keep=2, async_save=True)
    tree = {"w": np.random.default_rng(0).normal(size=(64, 64))}
    for step in (5, 10, 15):
        assert mgr.should_save(step)
        mgr.save(step, tree)
    mgr.wait()
    assert mgr.latest == 15
    # retention: only the last 2 kept
    committed = sorted(p.name for p in tmp_path.glob("COMMITTED_*"))
    assert len(committed) == 2


def test_checkpoint_manager_close_joins_inflight_save(tmp_path):
    """Fault-triggered teardown: ``close`` joins the in-flight async save —
    no orphaned writer thread racing the next restore — without raising, and
    the manager stays usable for the restarted run."""
    mgr = CheckpointManager(tmp_path, interval=1, keep=3, async_save=True)
    tree = {"w": np.random.default_rng(1).normal(size=(256, 64))}
    mgr.save(5, tree)
    assert mgr.close() is None
    assert mgr._thread is None              # writer joined, not abandoned
    assert mgr.latest == 5                  # the save was committed, not torn
    # a failing save: close() RETURNS the error instead of raising into the
    # (already-failing) teardown path, and clears it
    blocker = tmp_path / "step_000007"
    blocker.write_text("not a directory")   # save will trip over this file
    mgr.save(7, tree)
    err = mgr.close()
    assert err is not None
    assert mgr.close() is None              # error consumed, manager reusable
    mgr.save(9, tree)
    mgr.wait()
    assert mgr.latest == 9


# -- IDAG-orchestrated training -----------------------------------------------------
def test_train_loop_loss_decreases(tmp_path):
    loop = TrainLoop(CFG, global_batch=4, seq_len=32,
                     ckpt_dir=tmp_path / "ck", ckpt_interval=10)
    end, state, m = loop.run(12)
    assert end == 12
    assert len(m.losses) == 12
    assert m.losses[-1] < m.losses[0], m.losses
    assert latest_step(tmp_path / "ck") == 10


def test_checkpoint_restart_resumes_exactly(tmp_path):
    """Train 8 steps with a crash at step 5 -> restart -> final state must
    match an uninterrupted 8-step run (same data, same updates)."""
    ck = tmp_path / "ck"

    def fresh(ckdir):
        return TrainLoop(CFG, global_batch=4, seq_len=32, ckpt_dir=ckdir,
                         ckpt_interval=4, seed=0)

    # uninterrupted reference
    ref_loop = fresh(tmp_path / "ref")
    _, ref_state, ref_m = ref_loop.run(8)

    loop = fresh(ck)
    with pytest.raises(RuntimeError):
        loop.run(8, fail_at=5)
    # restart: checkpoint committed after step 4 -> resume at step 5
    loop2 = fresh(ck)
    start, state = loop2.restore_or_init()
    assert start == 5
    end, state, m = loop2.run(8 - start, start_step=start, state=state)
    assert end == 8
    for a, b in zip(jax_leaves(state["params"]), jax_leaves(ref_state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def jax_leaves(tree):
    import jax
    return jax.tree.leaves(tree)


def test_elastic_trainer_survives_failure(tmp_path):
    calls = []

    def make_loop(world_size):
        calls.append(world_size)
        return TrainLoop(CFG, global_batch=4, seq_len=32,
                         ckpt_dir=tmp_path / "ck", ckpt_interval=3, seed=0)

    et = ElasticTrainer(make_loop)
    state, metrics, world = et.run(10, world_size=4, fail_at=7)
    assert metrics.restarts == 1
    assert world == 3                       # lost a node, kept going
    assert calls == [4, 3]
    assert max(metrics.steps) == 9          # reached the end


# -- serving ------------------------------------------------------------------------
def test_serve_loop_batches_requests():
    sl = ServeLoop(CFG, max_batch=3, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [sl.submit(rng.integers(0, CFG.vocab_size, size=5), max_new=4)
            for _ in range(5)]
    sl.run_until_idle()
    for r in reqs:
        assert r.done.is_set()
        assert len(r.output) == 4
        assert all(0 <= t < CFG.vocab_size for t in r.output)
    assert sl.stats["batches"] == 2         # 3 + 2


def test_serve_greedy_matches_unbatched():
    """Batched greedy decode must equal the single-request result."""
    import jax.numpy as jnp
    sl = ServeLoop(CFG, max_batch=2, max_len=64)
    p1 = np.arange(1, 7)
    p2 = np.arange(3, 12)
    r1 = sl.submit(p1, max_new=5)
    r2 = sl.submit(p2, max_new=5)
    sl.run_until_idle()
    sl2 = ServeLoop(CFG, max_batch=1, max_len=64)
    sl2.params = sl.params
    q = sl2.submit(p2, max_new=5)
    sl2.run_until_idle()
    assert r2.output == q.output


# -- straggler mitigation ----------------------------------------------------------
def test_rebalance_weights():
    w = rebalance_weights({"device.0": 0.001, "device.1": 0.004,
                           "host": 0.01})
    assert set(w) == {"device.0", "device.1"}
    assert w["device.0"] > w["device.1"]
    assert abs(sum(w.values()) - 2.0) < 1e-6


# -- gradient compression -------------------------------------------------------------
def test_grad_compression_roundtrip_and_error_feedback():
    import jax
    from repro.optim import compress_grads, decompress_grads
    rng = np.random.default_rng(0)
    grads = {"w": rng.normal(size=(300,)).astype(np.float32) * 0.01,
             "b": rng.normal(size=(7,)).astype(np.float32)}
    grads = jax.tree.map(lambda x: __import__("jax.numpy", fromlist=["asarray"]).asarray(x), grads)
    comp, err = compress_grads(grads)
    out = decompress_grads(comp)
    for k in grads:
        rel = np.abs(np.asarray(out[k]) - np.asarray(grads[k])).max()
        scale = np.abs(np.asarray(grads[k])).max()
        assert rel <= scale / 100, f"{k}: {rel} vs {scale}"
    # error feedback: quantization residual is carried, not lost
    comp2, err2 = compress_grads(grads, err)
    recovered = decompress_grads(comp2)
    # mean of two dequantized versions closer to truth than one
    err_a = np.abs(np.asarray(out["w"]) - np.asarray(grads["w"])).mean()
    two = (np.asarray(out["w"]) + np.asarray(recovered["w"])) / 2
    err_b = np.abs(two - np.asarray(grads["w"])).mean()
    assert err_b <= err_a * 1.01
