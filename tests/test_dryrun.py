"""Dry-run infrastructure tests.

* the loop-aware HLO analyzer is validated against fully-unrolled compiles
  (where XLA's own cost_analysis is exact);
* sharding rules produce divisible PartitionSpecs for every arch;
* a subprocess runs a real (reduced-device) multi-mesh dry-run end-to-end.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hloanalysis import analyze

ROOT = Path(__file__).resolve().parents[1]


# -- HLO analyzer vs unrolled ground truth -------------------------------------
def _flops_truth(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    raw = c.cost_analysis()
    if isinstance(raw, (list, tuple)):       # older JAX returns [dict]
        raw = raw[0] if raw else {}
    return float(raw.get("flops", 0.0)), c


@pytest.mark.parametrize("n_iter", [4, 16])
def test_analyzer_counts_scan_loops(n_iter):
    d = 256
    w = jax.ShapeDtypeStruct((d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((8, d), jnp.float32)

    def scan_fn(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=n_iter)
        return c

    def unroll_fn(x, w):
        for _ in range(n_iter):
            x = jnp.tanh(x @ w)
        return x

    truth, _ = _flops_truth(unroll_fn, x, w)
    _, scan_c = _flops_truth(scan_fn, x, w)
    got = analyze(scan_c.as_text())["flops"]
    assert abs(got - truth) / truth < 0.05, (got, truth)


def test_analyzer_counts_grad_scan():
    d, L = 128, 6
    w = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((4, d), jnp.float32)

    def loss_scan(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return jnp.sum(c * c)

    def loss_unroll(w, x):
        c = x
        for i in range(L):
            c = jnp.tanh(c @ w[i])
        return jnp.sum(c * c)

    truth, _ = _flops_truth(jax.grad(loss_unroll), w, x)
    _, scan_c = _flops_truth(jax.grad(loss_scan), w, x)
    got = analyze(scan_c.as_text())["flops"]
    # the analyzer counts dot flops only; at d=128 the tanh-derivative
    # elementwise flops XLA counts are a visible share (conservative bias)
    assert abs(got - truth) / truth < 0.20, (got, truth)
    assert got <= truth * 1.02   # never overcount


def test_analyzer_bytes_reasonable():
    """Bytes must at least cover inputs+outputs, and not explode."""
    d = 512
    a = jax.ShapeDtypeStruct((d, d), jnp.float32)

    def f(a, b):
        return a @ b

    c = jax.jit(f).lower(a, a).compile()
    got = analyze(c.as_text())["bytes"]
    io = 3 * d * d * 4
    assert io <= got <= 3 * io, (got, io)


# -- sharding rules ---------------------------------------------------------------
def test_sharding_rules_divide_all_archs():
    """Every param spec must evenly divide its tensor on the (4,2) dev mesh
    (same divisibility logic as the production mesh)."""
    from repro.configs import ARCHITECTURES, get_config
    from repro.launch.inputs import param_specs
    from repro.sharding import param_shardings
    if len(jax.devices()) < 8:
        mesh_shape = (1, 1)
    else:
        mesh_shape = (4, 2)
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    for arch in ARCHITECTURES:
        cfg = get_config(arch)
        _, pspecs = param_specs(cfg)
        shards = param_shardings(pspecs, mesh)

        def check(leaf, ns):
            spec = ns.spec
            for dim, s in zip(leaf.shape, tuple(spec)):
                if s is None:
                    continue
                axes = s if isinstance(s, tuple) else (s,)
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                assert dim % size == 0, (arch, leaf.shape, spec)

        jax.tree.map(check, pspecs, shards)


# -- end-to-end dry-run in a subprocess (reduced device count) --------------------
DRYRUN_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json
import jax
from repro.launch import dryrun as D
from repro.configs import SHAPES

# shrink the production mesh for the test harness
import repro.launch.mesh as M
def small_mesh(*, multi_pod=False):
    return (jax.make_mesh((2, 2, 4), ("pod", "data", "model")) if multi_pod
            else jax.make_mesh((4, 4), ("data", "model")))
M.make_production_mesh = small_mesh
D.make_production_mesh = small_mesh

shapes = dict(SHAPES)
shapes["train_4k"] = dict(seq_len=256, global_batch=16, kind="train")
D.SHAPES.update(shapes)

from repro.configs import get_config
cfg = get_config("qwen2_1_5b", reduced=True)
rec = D.lower_cell("qwen2_1_5b", "train_4k", multi_pod=False, cfg=cfg)
rec2 = D.lower_cell("qwen2_1_5b", "train_4k", multi_pod=True, cfg=cfg)
assert rec["flops"] > 0 and rec2["flops"] > 0
assert rec["chips"] == 16 and rec2["chips"] == 16
print(json.dumps({"single": rec["flops"], "multi": rec2["flops"]}))
"""


def test_dryrun_subprocess_small_mesh():
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", DRYRUN_SNIPPET], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["single"] > 0


def test_dryrun_artifacts_complete():
    """The full 80-cell dry-run must have run with no errors."""
    art = ROOT / "artifacts" / "dryrun"
    if not art.exists():
        pytest.skip("dry-run artifacts not generated yet")
    recs = [json.loads(f.read_text()) for f in art.glob("*.json")]
    assert len(recs) == 80, f"expected 80 cells, found {len(recs)}"
    errors = [r for r in recs if "error" in r]
    assert not errors, errors[:3]
    ok = [r for r in recs if "flops" in r]
    skipped = [r for r in recs if "skipped" in r]
    # exactly the documented long_500k skips (7 archs x 2 meshes)
    assert len(skipped) == 14
    for r in ok:
        assert r["flops"] > 0
        assert r["memory"]["temp_bytes"] >= 0
