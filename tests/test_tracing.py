"""Tracer tests: overlap analysis invariants + Chrome/Perfetto export."""

import json

import numpy as np

from repro.core import Runtime, Tracer, one_to_one, read, read_write, reduction
from repro.core.tracing import Span


def test_chrome_trace_export_structure(tmp_path):
    tr = Tracer()
    tr.span("main", "task", "t0", 0.0, 1e-3)
    tr.span("sched-N0", "cdag", "t0", 5e-4, 2e-3)
    tr.span("N0.device.0", "device_kernel", "k", 2e-3, 4e-3)
    out = tmp_path / "trace.json"
    n = tr.to_chrome_trace(out)
    data = json.loads(out.read_text())
    events = data["traceEvents"]
    assert n == len(events) == 6          # 3 thread-name metadata + 3 spans
    meta = [e for e in events if e["ph"] == "M"]
    assert {e["args"]["name"] for e in meta} == {"main", "sched-N0",
                                                 "N0.device.0"}
    spans = [e for e in events if e["ph"] == "X"]
    for e in spans:
        assert e["ts"] >= 0 and e["dur"] > 0 and e["pid"] == 1
    k = next(e for e in spans if e["name"] == "k")
    assert k["cat"] == "device_kernel"
    assert k["ts"] == 2e3 and k["dur"] == 2e3    # microseconds


def test_chrome_trace_from_live_runtime(tmp_path):
    with Runtime(num_nodes=2, devices_per_node=1, trace=True) as rt:
        X = rt.buffer((8,), init=np.arange(8.0), name="X")
        E = rt.buffer((1,), init=np.zeros(1), name="E")

        def k(chunk, xv, red):
            red.contribute(xv.get(chunk))

        rt.submit("k", (8,), [read(X, one_to_one()), reduction(E, "sum")], k)
        rt.sync()
        tr = rt.tracer
    out = tmp_path / "live.json"
    tr.to_chrome_trace(out)
    events = json.loads(out.read_text())["traceEvents"]
    cats = {e.get("cat") for e in events if e["ph"] == "X"}
    # the reduction pipeline is visible in the exported timeline; the
    # partial exchange runs as collective rounds (DESIGN.md §9) on their
    # own per-collective lane
    assert {"fill_identity", "local_reduce", "coll_send", "coll_recv",
            "global_reduce"} <= cats
    lanes = {e["args"]["name"] for e in events if e["ph"] == "M"}
    assert any(".coll." in name for name in lanes), lanes


def test_zero_length_spans_get_min_duration(tmp_path):
    tr = Tracer()
    tr.span("l", "kind", "instant", 1e-3, 1e-3)
    out = tmp_path / "z.json"
    tr.to_chrome_trace(out)
    spans = [e for e in json.loads(out.read_text())["traceEvents"]
             if e["ph"] == "X"]
    assert spans[0]["dur"] > 0              # Perfetto drops dur=0 events


def test_busy_intervals_merge():
    spans = [Span("l", "k", "a", 0.0, 1.0), Span("l", "k", "b", 0.5, 2.0),
             Span("l", "k", "c", 3.0, 4.0)]
    assert Tracer._busy_intervals(spans) == [(0.0, 2.0), (3.0, 4.0)]
