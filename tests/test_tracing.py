"""Tracer tests: overlap analysis invariants + Chrome/Perfetto export."""

import json
import threading
from types import SimpleNamespace

import numpy as np

from repro.core import Runtime, Tracer, one_to_one, read, read_write, reduction
from repro.core.instructions import InstructionType
from repro.core.tracing import Span


def test_chrome_trace_export_structure(tmp_path):
    tr = Tracer()
    tr.span("main", "task", "t0", 0.0, 1e-3)
    tr.span("sched-N0", "cdag", "t0", 5e-4, 2e-3)
    tr.span("N0.device.0", "device_kernel", "k", 2e-3, 4e-3)
    out = tmp_path / "trace.json"
    n = tr.to_chrome_trace(out)
    data = json.loads(out.read_text())
    events = data["traceEvents"]
    assert n == len(events) == 6          # 3 thread-name metadata + 3 spans
    meta = [e for e in events if e["ph"] == "M"]
    assert {e["args"]["name"] for e in meta} == {"main", "sched-N0",
                                                 "N0.device.0"}
    spans = [e for e in events if e["ph"] == "X"]
    for e in spans:
        assert e["ts"] >= 0 and e["dur"] > 0 and e["pid"] == 1
    k = next(e for e in spans if e["name"] == "k")
    assert k["cat"] == "device_kernel"
    assert k["ts"] == 2e3 and k["dur"] == 2e3    # microseconds


def test_chrome_trace_from_live_runtime(tmp_path):
    with Runtime(num_nodes=2, devices_per_node=1, trace=True) as rt:
        X = rt.buffer((8,), init=np.arange(8.0), name="X")
        E = rt.buffer((1,), init=np.zeros(1), name="E")

        def k(chunk, xv, red):
            red.contribute(xv.get(chunk))

        rt.submit("k", (8,), [read(X, one_to_one()), reduction(E, "sum")], k)
        rt.sync()
        tr = rt.tracer
    out = tmp_path / "live.json"
    tr.to_chrome_trace(out)
    events = json.loads(out.read_text())["traceEvents"]
    cats = {e.get("cat") for e in events if e["ph"] == "X"}
    # the reduction pipeline is visible in the exported timeline; the
    # partial exchange runs as collective rounds (DESIGN.md §9) on their
    # own per-collective lane
    assert {"fill_identity", "local_reduce", "coll_send", "coll_recv",
            "global_reduce"} <= cats
    lanes = {e["args"]["name"] for e in events if e["ph"] == "M"}
    assert any(".coll." in name for name in lanes), lanes


def test_zero_length_spans_get_min_duration(tmp_path):
    tr = Tracer()
    tr.span("l", "kind", "instant", 1e-3, 1e-3)
    out = tmp_path / "z.json"
    tr.to_chrome_trace(out)
    spans = [e for e in json.loads(out.read_text())["traceEvents"]
             if e["ph"] == "X"]
    assert spans[0]["dur"] > 0              # Perfetto drops dur=0 events


def test_busy_intervals_merge():
    spans = [Span("l", "k", "a", 0.0, 1.0), Span("l", "k", "b", 0.5, 2.0),
             Span("l", "k", "c", 3.0, 4.0)]
    assert Tracer._busy_intervals(spans) == [(0.0, 2.0), (3.0, 4.0)]


# -- round-trip export (DESIGN.md §11.4) --------------------------------------

def _export_live_trace(tmp_path):
    with Runtime(num_nodes=2, devices_per_node=2, trace=True) as rt:
        X = rt.buffer((64,), init=np.arange(64.0), name="X")
        E = rt.buffer((1,), init=np.zeros(1), name="E")

        def bump(chunk, xv):
            xv.set(chunk, xv.get(chunk) + 1)

        def tally(chunk, xv, red):
            red.contribute(xv.get(chunk).sum())

        for i in range(4):
            rt.submit(f"bump{i}", (64,), [read_write(X, one_to_one())], bump)
        rt.submit("tally", (64,),
                  [read(X, one_to_one()), reduction(E, "sum")], tally)
        rt.sync()
        out = tmp_path / "roundtrip.json"
        rt.tracer.to_chrome_trace(out)
        records = list(rt.tracer.records)
    return json.loads(out.read_text())["traceEvents"], records


def test_export_thread_metadata_covers_every_event(tmp_path):
    events, _ = _export_live_trace(tmp_path)
    named = {e["tid"] for e in events
             if e["ph"] == "M" and e["name"] == "thread_name"}
    used = {e["tid"] for e in events if "tid" in e}
    assert used <= named, f"events on unnamed threads: {used - named}"


def test_export_flow_links_are_well_formed(tmp_path):
    events, _ = _export_live_trace(tmp_path)
    starts = {(e["cat"], e["id"]): e["ts"] for e in events if e["ph"] == "s"}
    finishes = [e for e in events if e["ph"] == "f"]
    assert finishes, "no flow arrows exported"
    for e in finishes:
        key = (e["cat"], e["id"])
        assert key in starts, f"flow finish without start: {key}"
        assert starts[key] <= e["ts"] + 1e-6
    # both layers of arrows: task -> cdag ("t<tid>.N<node>") and
    # sched -> instruction ("i<node>.<iid>")
    ids = {e["id"] for e in finishes}
    assert any(i.startswith("t") for i in ids)
    assert any(i.startswith("i") for i in ids)


def test_export_instruction_flows_complete(tmp_path):
    events, records = _export_live_trace(tmp_path)
    flow_ids = {e["id"] for e in events if e["ph"] == "f"}
    linkable = [r for r in records if r.tid is not None]
    assert linkable
    missing = [f"i{r.node}.{r.iid}" for r in linkable
               if f"i{r.node}.{r.iid}" not in flow_ids]
    assert not missing, f"records without flow arrows: {missing[:5]}"


def test_export_wait_spans_balanced(tmp_path):
    events, records = _export_live_trace(tmp_path)
    waits = [e for e in events if e.get("cat") == "wait"]
    assert waits, "no wait-state spans exported"
    per_id: dict[str, int] = {}
    for e in waits:
        assert e["ph"] in ("b", "e")
        assert e["name"].startswith("wait:")
        per_id[e["id"]] = per_id.get(e["id"], 0) + (1 if e["ph"] == "b" else -1)
    assert all(v == 0 for v in per_id.values()), "unbalanced b/e pairs"
    # every wait id resolves to a traced instruction record
    rec_ids = {f"w{r.node}.{r.iid}" for r in records}
    assert set(per_id) <= rec_ids


def test_export_counter_tracks_present(tmp_path):
    events, _ = _export_live_trace(tmp_path)
    counters = [e for e in events if e["ph"] == "C"]
    assert counters
    for e in counters:
        assert "value" in e["args"]
    names = {e["name"] for e in counters}
    # scheduler-lag time series: executor in-flight depth is sampled at
    # every horizon, so it is always present on a traced run
    assert any(n.startswith("executor.N") and n.endswith(".inflight")
               for n in names), names


# -- issue/complete lock discipline -------------------------------------------

def _fake_instr(iid):
    return SimpleNamespace(iid=iid, name=f"i{iid}", queue=("host",),
                           itype=InstructionType.HOST_TASK, command=None)


def test_issue_complete_race_keeps_open_table_consistent():
    """Regression: ``issue``/``complete`` mutate ``_open`` under the tracer
    lock — concurrent executors must neither lose spans nor leak entries."""
    tr = Tracer()
    n_threads, per_thread = 8, 200

    def hammer(node):
        for k in range(per_thread):
            instr = _fake_instr(k)
            tr.issue(node, instr)
            tr.complete(node, instr)

    ts = [threading.Thread(target=hammer, args=(n,)) for n in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert tr._open == {}, "leaked open-span entries"
    assert len(tr.spans) == n_threads * per_thread
    for s in tr.spans:
        assert s.t0 <= s.t1


# -- sampled (1-in-N) record capture ------------------------------------------

def test_record_sampling_keeps_one_in_n():
    """``record_sample=N`` keeps every Nth completion record, counts the
    dropped ones, and leaves no leaked open-span entries behind."""
    tr = Tracer(record_sample=4)
    total = 40
    for k in range(total):
        instr = _fake_instr(k)
        tr.issue(0, instr)
        tr.record(0, instr, "N0.host", t_reg=0.0, t_ready=0.0,
                  t_start=0.0, t_done=1e-6, wait_cls="none", blame_iid=None)
    assert len(tr.records) == total // 4
    assert tr.records_sampled_out == total - total // 4
    assert tr._open == {}, "sampled-out records must still close open spans"


def test_record_sampling_default_records_everything():
    tr = Tracer()
    for k in range(10):
        tr.record(0, _fake_instr(k), "N0.host", t_reg=0.0, t_ready=0.0,
                  t_start=0.0, t_done=1e-6, wait_cls="none", blame_iid=None)
    assert len(tr.records) == 10
    assert tr.records_sampled_out == 0


def test_record_sampling_thread_safe_counts():
    """Concurrent completion records: kept + dropped must account for every
    call exactly once (the modulo counter is lock-protected)."""
    tr = Tracer(record_sample=16)
    n_threads, per_thread = 8, 128

    def hammer(node):
        for k in range(per_thread):
            tr.record(node, _fake_instr(k), f"N{node}.host", t_reg=0.0,
                      t_ready=0.0, t_start=0.0, t_done=1e-6,
                      wait_cls="none", blame_iid=None)

    ts = [threading.Thread(target=hammer, args=(n,)) for n in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    total = n_threads * per_thread
    assert len(tr.records) + tr.records_sampled_out == total
    assert len(tr.records) == total // 16


def test_sampled_trace_still_analyzable():
    """A sampled trace must stay structurally valid: lanes() and the
    critical-path analyzer run on partial records without error."""
    from repro.core.observability import critical_path
    tr = Tracer(record_sample=3)
    rt = Runtime(1, 2)
    rt.tracer = tr
    for ex in rt.executors:
        ex.tracer = tr
    buf = rt.buffer((16,), init=np.zeros(16))
    for _ in range(6):
        rt.submit("inc", (16,), [read_write(buf, one_to_one())],
                  lambda c, v: v.set(c, v.get(c) + 1))
    out = rt.gather(buf)
    rt.shutdown()
    assert np.array_equal(out, np.full(16, 6.0))
    assert tr.records_sampled_out > 0
    lanes = tr.lanes()
    assert lanes                    # derived spans still render
    rep = critical_path(tr)
    assert rep.total_us >= 0.0
