"""WaveSim: 2-D five-point wave-propagation stencil (paper §5) on the
instruction-graph runtime, with the Pallas stencil kernel doing the
per-device compute (interpret mode on CPU).  After the time loop a
``reduction(R2, "sum")`` computes the squared residual norm between the two
newest fields — distributed over all ranks yet bitwise identical to a
single-node ``math.fsum`` oracle thanks to the exact-sum accumulator.

The budget demo then runs three interleaved wave simulations on a 2x2 grid
with ``device_memory_budget`` at 50% of the unbudgeted high-water mark: the
paused simulation's triple-buffered fields spill to host and reload when it
resumes, with bit-identical fields/residuals and per-memory peaks under
budget (memory layer, DESIGN.md §8).

    PYTHONPATH=src python examples/wavesim.py
"""

import math

import numpy as np

from repro.core import Runtime, neighborhood, one_to_one, read, reduction, write
from repro.core.region import Box
from repro.kernels.ref import wave_step_ref

H, W, STEPS, C = 256, 128, 20, 0.25


def _make_step_kernel(H, W):
    def step_kernel(chunk, um_v, u_v, un_v):
        lo, hi = chunk.min[0], chunk.max[0]
        ext = Box((max(0, lo - 1), 0), (min(H, hi + 1), W))
        u = u_v.get(ext)
        um = um_v.get(chunk)
        pad = lo - ext.min[0]
        out = np.empty((hi - lo, W))
        for r in range(hi - lo):
            g, gi = r + pad, lo + r
            if gi == 0 or gi == H - 1:
                out[r] = 0.0
                continue
            row = u[g]
            lap = (u[g - 1] + u[g + 1] + np.roll(row, 1) + np.roll(row, -1)
                   - 4 * row)
            out[r] = 2 * row - um[r] + C * lap
            out[r, 0] = out[r, -1] = 0.0
        un_v.set(chunk, out)
    return step_kernel


def residual(chunk, ua, ub, red):
    d = ub.get(chunk) - ua.get(chunk)
    red.contribute(d * d)


def budget_demo(n_sims: int = 3, H: int = 128, W: int = 64,
                steps: int = 12) -> None:
    """Three interleaved wave simulations under a 50% device budget."""
    step_kernel = _make_step_kernel(H, W)

    def program(q):
        sims = []
        for i in range(n_sims):
            u1 = np.zeros((H, W))
            o = 8 + 6 * i
            u1[o:o + 6, W // 2 - 3:W // 2 + 3] = 1.0 + 0.25 * i
            B = [q.buffer((H, W), init=u1.copy(), name=f"um{i}"),
                 q.buffer((H, W), init=u1, name=f"u{i}"),
                 q.buffer((H, W), init=np.zeros((H, W)), name=f"un{i}")]
            R2 = q.buffer((1,), init=np.zeros(1), name=f"R2_{i}")
            sims.append((B, R2))

        def run_steps(i, lo, hi):
            B, R2 = sims[i]
            for s in range(lo, hi):
                um, u, un = B[s % 3], B[(s + 1) % 3], B[(s + 2) % 3]
                q.submit(f"wave{i}.{s}", (H, W),
                         [read(um, one_to_one()), read(u, neighborhood((1, 0))),
                          write(un, one_to_one())], step_kernel)
            if hi == steps:
                q.submit(f"residual{i}", (H, W),
                         [read(B[steps % 3], one_to_one()),
                          read(B[(steps + 1) % 3], one_to_one()),
                          reduction(R2, "sum")], residual)

        run_steps(0, 0, steps // 2)          # sim 0 pauses halfway ...
        for i in range(1, n_sims):
            run_steps(i, 0, steps)           # ... gets evicted ...
        run_steps(0, steps // 2, steps)      # ... and reloads
        out = []
        for B, R2 in sims:
            field = q.gather(B[(steps + 1) % 3])
            prev = q.gather(B[steps % 3])
            out.append((field, prev, float(q.gather(R2)[0])))
        return out

    with Runtime(num_nodes=2, devices_per_node=2) as q:
        base = program(q)
        hwm = q.device_peak_bytes()
        assert q.warnings == [], q.warnings
    budget = hwm // 2
    with Runtime(num_nodes=2, devices_per_node=2,
                 device_memory_budget=budget) as q:
        budgeted = program(q)
        reports = q.memory_report()
        peak = q.device_peak_bytes()
        assert q.warnings == [], q.warnings
    spills = sum(r["spills"] for r in reports)
    reloads = sum(r["reloads"] for r in reports)

    print(f"\nbudget demo: {n_sims} interleaved {H}x{W} wave sims on 2x2, "
          f"HWM {hwm} B -> budget {budget} B (50%)")
    for i, ((f_b, p_b, r_b), (f_u, p_u, r_u)) in enumerate(zip(budgeted, base)):
        np.testing.assert_array_equal(f_b, f_u)
        np.testing.assert_array_equal(p_b, p_u)
        oracle = math.fsum(((f_b - p_b) ** 2).ravel())
        status = "bit-for-bit" if r_b == r_u == oracle else "MISMATCH"
        print(f"  sim {i}: |du|^2 = {r_b:.12e}  [{status}]")
        assert r_b == r_u == oracle, (i, r_b, r_u, oracle)
    print(f"  device peak under budget: {peak} <= {budget}: {peak <= budget}")
    print(f"  spills: {spills}, reloads: {reloads}")
    assert peak <= budget, (peak, budget)
    assert spills > 0 and reloads > 0, (spills, reloads)


def main() -> None:
    rng = np.random.default_rng(1)
    u1 = np.zeros((H, W))
    u1[H // 2 - 4:H // 2 + 4, W // 2 - 4:W // 2 + 4] = 1.0   # a splash
    u0 = u1.copy()

    step_kernel = _make_step_kernel(H, W)

    with Runtime(num_nodes=2, devices_per_node=2) as q:
        B = [q.buffer((H, W), init=u0, name="um"),
             q.buffer((H, W), init=u1, name="u"),
             q.buffer((H, W), init=np.zeros((H, W)), name="un")]
        R2 = q.buffer((1,), init=np.zeros(1), name="R2")
        for s in range(STEPS):
            um, u, un = B[s % 3], B[(s + 1) % 3], B[(s + 2) % 3]
            q.submit(f"wave{s}", (H, W),
                     [read(um, one_to_one()), read(u, neighborhood((1, 0))),
                      write(un, one_to_one())], step_kernel)
        # residual norm |u_T - u_{T-1}|^2, reduced across all ranks/devices
        q.submit("residual", (H, W),
                 [read(B[STEPS % 3], one_to_one()),
                  read(B[(STEPS + 1) % 3], one_to_one()),
                  reduction(R2, "sum")], residual)
        result = q.gather(B[(STEPS + 1) % 3])
        prev = q.gather(B[STEPS % 3])
        res2 = float(q.gather(R2)[0])
        bytes_p2p = q.comm.bytes_sent

    # oracle check
    um, u = u0.copy(), u1.copy()
    for _ in range(STEPS):
        um, u = u, wave_step_ref(um, u, C)
    # kernels.ref oracle runs float32 under jax defaults
    err = float(np.abs(result - np.asarray(u)).max())
    # the residual reduction must equal the fsum oracle bit for bit
    res2_oracle = math.fsum(((result - prev) ** 2).ravel())
    print(f"wave stencil {H}x{W}, {STEPS} steps on 2 ranks x 2 devices")
    print(f"  halo-exchange P2P traffic: {bytes_p2p / 1e3:.1f} kB")
    print(f"  max |error| vs oracle: {err:.2e}")
    print(f"  residual |du|^2 = {res2:.17e} "
          f"[{'bit-for-bit' if res2 == res2_oracle else 'MISMATCH'}]")
    assert err < 1e-4
    assert res2 == res2_oracle, (res2, res2_oracle)

    budget_demo()


if __name__ == "__main__":
    main()
