"""N-body with distributed total-energy + momentum reductions (§2.2 + §9).

The dynamics run exactly like ``quickstart.py``; every few steps two
adjacent kernels bind scalar reductions — ``reduction(E, "sum")`` (total
energy) and ``reduction(Mx, "sum")`` (x-momentum).  The runtime
identity-fills per-device partials, folds them per node, and runs a
**reduce-scatter + allgather allreduce** between the ranks (recursive
halving with fold-on-receive, then a dissemination allgather of the
folded shards — DESIGN.md §9; 2-node grids keep the byte-equivalent
full-partial exchange); the adjacent ``E``/``Mx`` reductions
**fuse into one packed exchange** (2 exchanges -> 1 per step).  The
exact-sum accumulator is associative and commutative in exact integer
arithmetic, so both results are **bitwise identical** to a single-node
``math.fsum`` oracle on any rank/device grid, fused or not, under any
exchange topology.

The second half demonstrates the budgeted memory layer (DESIGN.md §8):
three independent simulations share one runtime, phase 0 pausing while the
others run.  With ``device_memory_budget`` at 50% of the unbudgeted
high-water mark the paused simulation's buffers are spilled to host and
lazily reloaded when it resumes — and every energy stays bit-for-bit equal
to the unbudgeted run and the fsum oracle, with per-memory peaks under
budget.

Run:  PYTHONPATH=src python examples/nbody.py
"""

import math

import numpy as np

from repro.core import (Runtime, all_range, one_to_one, read, read_write,
                        reduction)
from repro.core.region import Box

N, STEPS, DT, MASS, EPS = 512, 8, 0.01, 1.0, 1e-3
ENERGY_EVERY = 4


def body_energies(P, Vrows, lo, hi):
    """Per-body energy e_i for rows [lo, hi): kinetic + half the softened
    pair potential.  Row i depends only on global data, so the values are
    identical under any chunking — partition independence of the total
    then follows from the exact-sum reduction accumulator."""
    d = P[None, :, :] - P[lo:hi, None, :]
    r2 = (d * d).sum(-1) + EPS
    pot = -0.5 * MASS * MASS / np.sqrt(r2)
    for r in range(hi - lo):
        pot[r, lo + r] = 0.0          # no self-interaction
    kin = 0.5 * MASS * (Vrows ** 2).sum(-1)
    return kin + pot.sum(1)


def _oracle_run(P, V, steps):
    P, V = P.copy(), V.copy()
    for _ in range(steps):
        d = P[None, :, :] - P[:, None, :]
        r2 = (d * d).sum(-1) + EPS
        F = (d / r2[..., None] ** 1.5).sum(1)
        V = V + MASS * F * DT
        P = P + V * DT
    return P, V


def budget_demo(n_sims: int = 3, n_bodies: int = 256, steps: int = 8) -> None:
    """Three phased simulations under a 50% device-memory budget."""
    inits = [(_rng.normal(size=(n_bodies, 3)), _rng.normal(size=(n_bodies, 3)) * 0.1)
             for _rng in (np.random.default_rng(100 + i) for i in range(n_sims))]

    def program(q):
        sims = [(q.buffer((n_bodies, 3), init=P0, name=f"P{i}"),
                 q.buffer((n_bodies, 3), init=V0, name=f"V{i}"),
                 q.buffer((1,), init=np.zeros(1), name=f"E{i}"))
                for i, (P0, V0) in enumerate(inits)]

        def run_steps(i, lo, hi):
            P, V, E = sims[i]

            def timestep(chunk, p, v):
                Pa = p.get(Box((0, 0), (n_bodies, 3)))
                a, b = chunk.min[0], chunk.max[0]
                d = Pa[None, :, :] - Pa[a:b, None, :]
                r2 = (d * d).sum(-1) + EPS
                F = (d / r2[..., None] ** 1.5).sum(1)
                v.set(chunk, v.get(chunk) + MASS * F * DT)

            def update(chunk, v, p):
                p.set(chunk, p.get(chunk) + v.get(chunk) * DT)

            def energy(chunk, p, v, red):
                Pa = p.get(Box((0, 0), (n_bodies, 3)))
                a, b = chunk.min[0], chunk.max[0]
                red.contribute(body_energies(Pa, v.get(chunk), a, b))

            for _ in range(lo, hi):
                q.submit(f"timestep{i}", (n_bodies, 3),
                         [read(P, all_range()), read_write(V, one_to_one())],
                         timestep)
                q.submit(f"update{i}", (n_bodies, 3),
                         [read(V, one_to_one()), read_write(P, one_to_one())],
                         update)
            if hi == steps:
                q.submit(f"energy{i}", (n_bodies, 3),
                         [read(P, all_range()), read(V, one_to_one()),
                          reduction(E, "sum")], energy)

        # phase 0 pauses at the halfway point while sims 1..n run to the
        # end — under budget its buffers are spilled, then reloaded
        run_steps(0, 0, steps // 2)
        for i in range(1, n_sims):
            run_steps(i, 0, steps)
        run_steps(0, steps // 2, steps)
        return [float(q.gather(E)[0]) for _, _, E in sims]

    with Runtime(num_nodes=1, devices_per_node=1) as q:
        base = program(q)
        hwm = q.device_peak_bytes()
        assert q.warnings == [], q.warnings

    budget = hwm // 2
    with Runtime(num_nodes=1, devices_per_node=1,
                 device_memory_budget=budget) as q:
        budgeted = program(q)
        reports = q.memory_report()
        peak = q.device_peak_bytes()
        assert q.warnings == [], q.warnings
    spills = sum(r["spills"] for r in reports)
    reloads = sum(r["reloads"] for r in reports)

    print(f"\nbudget demo: {n_sims} phased simulations, "
          f"unbudgeted device HWM {hwm} B -> budget {budget} B (50%)")
    for i, (e_b, e_u) in enumerate(zip(budgeted, base)):
        P0, V0 = inits[i]
        Pf, Vf = _oracle_run(P0, V0, steps)
        oracle = math.fsum(body_energies(Pf, Vf, 0, n_bodies))
        status = "bit-for-bit" if e_b == e_u == oracle else "MISMATCH"
        print(f"  sim {i}: E = {e_b:+.15e}  [{status}]")
        assert e_b == e_u == oracle, (i, e_b, e_u, oracle)
    print(f"  device peak under budget: {peak} <= {budget}: {peak <= budget}")
    print(f"  spills: {spills}, reloads: {reloads}")
    assert peak <= budget, (peak, budget)
    assert spills > 0 and reloads > 0, (spills, reloads)


def observability_demo(n_bodies: int = 2 * N, steps: int = 12,
                       attempts: int = 3) -> None:
    """Traced 2x2 run: critical-path attribution (DESIGN.md §11).

    The paper's claim that instruction-graph scheduling stays off the
    critical path, quantified: the flight recorder decomposes the traced
    run's longest chain by pipeline layer, and the scheduler lanes must
    account for <10% of it.  Also checks the recorder's core invariant —
    per instruction, classified pending wait + queue wait reconstruct the
    measured issue latency exactly (within 1%).  Container co-tenancy can
    stall worker threads and inflate every lane at once, so the share is
    taken best-of-``attempts`` (the invariant checks run on every
    attempt); noise only ever inflates the scheduler share.
    """
    best = None
    for attempt in range(attempts):
        frac = _observability_run(n_bodies, steps)
        best = frac if best is None else min(best, frac)
        if best < 0.10:
            break
    # the paper's off-critical-path claim, quantified
    assert best < 0.10, f"scheduler on critical path: {best:.1%}"
    print(f"  scheduler lanes under the 10% budget: {best:.2%} < 10%")


def _observability_run(n_bodies: int, steps: int) -> float:
    with Runtime(num_nodes=2, devices_per_node=2, trace=True) as q:
        P = q.buffer((n_bodies, 3),
                     init=np.random.default_rng(7).normal(
                         size=(n_bodies, 3)), name="P")
        V = q.buffer((n_bodies, 3), init=np.zeros((n_bodies, 3)), name="V")
        E = q.buffer((1,), init=np.zeros(1), name="E")

        def timestep(chunk, p, v):
            Pa = p.get(Box((0, 0), (n_bodies, 3)))
            lo, hi = chunk.min[0], chunk.max[0]
            d = Pa[None, :, :] - Pa[lo:hi, None, :]
            r2 = (d * d).sum(-1) + EPS
            F = (d / r2[..., None] ** 1.5).sum(1)
            v.set(chunk, v.get(chunk) + MASS * F * DT)

        def update(chunk, v, p):
            p.set(chunk, p.get(chunk) + v.get(chunk) * DT)

        def energy(chunk, p, v, red):
            Pa = p.get(Box((0, 0), (n_bodies, 3)))
            lo, hi = chunk.min[0], chunk.max[0]
            red.contribute(body_energies(Pa, v.get(chunk), lo, hi))

        for s in range(steps):
            q.submit("timestep", (n_bodies, 3),
                     [read(P, all_range()), read_write(V, one_to_one())],
                     timestep)
            q.submit("update", (n_bodies, 3),
                     [read(V, one_to_one()), read_write(P, one_to_one())],
                     update)
        q.submit("energy", (n_bodies, 3),
                 [read(P, all_range()), read(V, one_to_one()),
                  reduction(E, "sum")], energy)
        q.sync()

        rep = q.critical_path_report()
        print(f"\ncritical-path attribution (2x2 grid, {steps} steps):")
        print(rep.render())

        # wait-state decomposition is exact per instruction (within 1%)
        recs = q.tracer.records
        assert recs, "traced run recorded no instructions"
        for r in recs:
            lat = r.t_start - r.t_reg
            parts = (r.t_ready - r.t_reg) + (r.t_start - r.t_ready)
            assert abs(parts - lat) <= 1e-9 + 0.01 * max(lat, 1e-12), \
                (r.node, r.iid, parts, lat)
        # registry histograms aggregate the same ground truth
        hists = q.metrics()["histograms"]
        for n in range(2):
            h = hists[f"executor.N{n}.issue_us"]
            rec_sum = sum((r.t_start - r.t_reg) * 1e6
                          for r in recs if r.node == n)
            assert abs(h["sum_us"] - rec_sum) <= 0.01 * max(rec_sum, 1e-9), \
                (n, h["sum_us"], rec_sum)
        print(f"  wait decomposition exact for all {len(recs)} "
              f"instructions; histograms match records on both nodes")

        # per-lane busy/idle occupancy from the same records (DESIGN.md §13)
        util = q.utilization_report()
        print(f"  lane utilization over {util['span_us'] / 1e3:.2f} ms span "
              f"(mean occupancy {util['occupancy']:.1%}, device occupancy "
              f"{util['device_occupancy']:.1%}):")
        for lane, row in util["lanes"].items():
            print(f"    {lane:<16} busy {row['busy_us'] / 1e3:8.3f} ms "
                  f"({row['busy_frac']:6.1%})  "
                  f"{row['instructions']} instructions")

        return rep.scheduler_fraction


def main() -> None:
    from repro.core.collective import allreduce_message_count

    rng = np.random.default_rng(42)
    P0 = rng.normal(size=(N, 3))
    V0 = rng.normal(size=(N, 3)) * 0.1

    results = {}
    # 1x1, 2x2 and a non-power-of-two grid; ``fusion=False`` is the
    # unfused oracle run that must agree bit-for-bit with the fused one
    for nodes, devs, fusion in [(1, 1, True), (2, 2, True), (3, 1, True),
                                (2, 2, False)]:
        with Runtime(num_nodes=nodes, devices_per_node=devs,
                     reduction_fusion=fusion) as q:
            P = q.buffer((N, 3), init=P0, name="P")
            V = q.buffer((N, 3), init=V0, name="V")
            E = q.buffer((1,), init=np.zeros(1), name="E")
            Mx = q.buffer((1,), init=np.zeros(1), name="Mx")

            def timestep(chunk, p, v):
                Pa = p.get(Box((0, 0), (N, 3)))
                lo, hi = chunk.min[0], chunk.max[0]
                d = Pa[None, :, :] - Pa[lo:hi, None, :]
                r2 = (d * d).sum(-1) + EPS
                F = (d / r2[..., None] ** 1.5).sum(1)
                v.set(chunk, v.get(chunk) + MASS * F * DT)

            def update(chunk, v, p):
                p.set(chunk, p.get(chunk) + v.get(chunk) * DT)

            def energy(chunk, p, v, red):
                Pa = p.get(Box((0, 0), (N, 3)))
                lo, hi = chunk.min[0], chunk.max[0]
                red.contribute(body_energies(Pa, v.get(chunk), lo, hi))

            def momentum(chunk, v, red):
                red.contribute(MASS * v.get(chunk)[:, 0])

            for s in range(STEPS):
                q.submit("timestep", (N, 3),
                         [read(P, all_range()), read_write(V, one_to_one())],
                         timestep)
                q.submit("update", (N, 3),
                         [read(V, one_to_one()), read_write(P, one_to_one())],
                         update)
                if (s + 1) % ENERGY_EVERY == 0:
                    # adjacent E + Mx reductions: ONE packed exchange (§9)
                    q.submit("energy", (N, 3),
                             [read(P, all_range()), read(V, one_to_one()),
                              reduction(E, "sum")], energy)
                    q.submit("momentum", (N, 3),
                             [read(V, one_to_one()), reduction(Mx, "sum")],
                             momentum)
            result = q.gather(E)
            mom = q.gather(Mx)
            Pg = q.gather(P)
            stats = q.comm_stats()
            assert q.warnings == [], q.warnings
        # the reduction exchange is a reduce-scatter + shard allgather
        # allreduce (DESIGN.md §9); its replicated schedule fixes the
        # wire-message count per exchange
        group = tuple(range(nodes))
        per_exchange = allreduce_message_count(group, group, 1)
        exchanges = (stats["red_messages"] // per_exchange
                     if per_exchange else 0)
        results[(nodes, devs, fusion)] = (float(result[0]), float(mom[0]),
                                          Pg, exchanges)

    # single-node numpy oracle: same per-body values, math.fsum combine
    P, V = _oracle_run(P0, V0, STEPS)
    oracle = math.fsum(body_energies(P, V, 0, N))
    oracle_mx = math.fsum(MASS * V[:, 0])
    n_red_steps = STEPS // ENERGY_EVERY

    print(f"n-body total energy + x-momentum after {STEPS} steps ({N} bodies):")
    for (nodes, devs, fusion), (e, mx, Pg, exchanges) in results.items():
        match = "bit-for-bit" if (e, mx) == (oracle, oracle_mx) \
            else f"MISMATCH ({e - oracle:+.3e})"
        tag = "fused" if fusion else "unfused oracle"
        print(f"  {nodes}x{devs} ({tag}): E = {e:+.15e}  Mx = {mx:+.10e}  "
              f"[{match}]")
        assert e == oracle and mx == oracle_mx, (e, oracle, mx, oracle_mx)
        np.testing.assert_array_equal(Pg, P)
        if nodes > 1:
            # fused: exactly ONE reduction exchange per energy step;
            # unfused: two (E and Mx separately)
            want = n_red_steps if fusion else 2 * n_red_steps
            assert exchanges == want, (fusion, exchanges, want)
    print(f"  oracle (math.fsum):  E = {oracle:+.15e}  Mx = {oracle_mx:+.10e}")
    print(f"  fused reduction exchanges per energy step: 1 (vs 2 unfused)")

    budget_demo()
    observability_demo()


if __name__ == "__main__":
    main()
