"""N-body with a distributed total-energy reduction (paper listing 1 + §2.2).

The dynamics run exactly like ``quickstart.py``; every few steps a kernel
binds a scalar ``reduction(E, "sum")`` next to its accessors and contributes
each body's energy.  The runtime identity-fills per-device partials, folds
them per node, broadcasts/gathers the partials between all ranks
(``GATHER_RECEIVE``) and folds them in canonical node order
(``GLOBAL_REDUCE``) — the exact-sum accumulator makes the result **bitwise
identical** to a single-node ``math.fsum`` oracle on any rank/device grid.

Run:  PYTHONPATH=src python examples/nbody.py
"""

import math

import numpy as np

from repro.core import (Runtime, all_range, one_to_one, read, read_write,
                        reduction)
from repro.core.region import Box

N, STEPS, DT, MASS, EPS = 512, 8, 0.01, 1.0, 1e-3
ENERGY_EVERY = 4


def body_energies(P, Vrows, lo, hi):
    """Per-body energy e_i for rows [lo, hi): kinetic + half the softened
    pair potential.  Row i depends only on global data, so the values are
    identical under any chunking — partition independence of the total
    then follows from the exact-sum reduction accumulator."""
    d = P[None, :, :] - P[lo:hi, None, :]
    r2 = (d * d).sum(-1) + EPS
    pot = -0.5 * MASS * MASS / np.sqrt(r2)
    for r in range(hi - lo):
        pot[r, lo + r] = 0.0          # no self-interaction
    kin = 0.5 * MASS * (Vrows ** 2).sum(-1)
    return kin + pot.sum(1)


def main() -> None:
    rng = np.random.default_rng(42)
    P0 = rng.normal(size=(N, 3))
    V0 = rng.normal(size=(N, 3)) * 0.1

    results = {}
    for nodes, devs in [(1, 1), (2, 2), (4, 1)]:
        with Runtime(num_nodes=nodes, devices_per_node=devs) as q:
            P = q.buffer((N, 3), init=P0, name="P")
            V = q.buffer((N, 3), init=V0, name="V")
            E = q.buffer((1,), init=np.zeros(1), name="E")

            def timestep(chunk, p, v):
                Pa = p.get(Box((0, 0), (N, 3)))
                lo, hi = chunk.min[0], chunk.max[0]
                d = Pa[None, :, :] - Pa[lo:hi, None, :]
                r2 = (d * d).sum(-1) + EPS
                F = (d / r2[..., None] ** 1.5).sum(1)
                v.set(chunk, v.get(chunk) + MASS * F * DT)

            def update(chunk, v, p):
                p.set(chunk, p.get(chunk) + v.get(chunk) * DT)

            def energy(chunk, p, v, red):
                Pa = p.get(Box((0, 0), (N, 3)))
                lo, hi = chunk.min[0], chunk.max[0]
                red.contribute(body_energies(Pa, v.get(chunk), lo, hi))

            for s in range(STEPS):
                q.submit("timestep", (N, 3),
                         [read(P, all_range()), read_write(V, one_to_one())],
                         timestep)
                q.submit("update", (N, 3),
                         [read(V, one_to_one()), read_write(P, one_to_one())],
                         update)
                if (s + 1) % ENERGY_EVERY == 0:
                    q.submit("energy", (N, 3),
                             [read(P, all_range()), read(V, one_to_one()),
                              reduction(E, "sum")], energy)
            result = q.gather(E)
            Pg = q.gather(P)
            assert q.warnings == [], q.warnings
        results[(nodes, devs)] = (float(result[0]), Pg)

    # single-node numpy oracle: same per-body energies, math.fsum combine
    P, V = P0.copy(), V0.copy()
    for s in range(STEPS):
        d = P[None, :, :] - P[:, None, :]
        r2 = (d * d).sum(-1) + EPS
        F = (d / r2[..., None] ** 1.5).sum(1)
        V = V + MASS * F * DT
        P = P + V * DT
    oracle = math.fsum(body_energies(P, V, 0, N))

    print(f"n-body total energy after {STEPS} steps ({N} bodies):")
    for (nodes, devs), (e, Pg) in results.items():
        match = "bit-for-bit" if e == oracle else f"MISMATCH ({e - oracle:+.3e})"
        print(f"  {nodes} nodes x {devs} devices: E = {e:+.15e}  [{match}]")
        assert e == oracle, (e, oracle)
        np.testing.assert_array_equal(Pg, P)
    print(f"  oracle (math.fsum):    E = {oracle:+.15e}")


if __name__ == "__main__":
    main()
