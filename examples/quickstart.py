"""Quickstart: the paper's Listing-1 N-body simulation on the
instruction-graph runtime — 2 simulated ranks x 2 devices each, with
transparent work assignment, buffer virtualization and P2P exchange.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import Runtime, all_range, one_to_one, read, read_write
from repro.core.region import Box

N, STEPS, DT, MASS = 1024, 10, 0.01, 1.0


def gravity_forces(P, lo, hi):
    d = P[None, :, :] - P[lo:hi, None, :]
    r2 = (d * d).sum(-1) + 1e-3
    return (d / r2[..., None] ** 1.5).sum(1)


def main() -> None:
    rng = np.random.default_rng(42)
    P0 = rng.normal(size=(N, 3))
    V0 = rng.normal(size=(N, 3)) * 0.1

    with Runtime(num_nodes=2, devices_per_node=2, trace=True) as q:
        P = q.buffer((N, 3), init=P0, name="P")
        V = q.buffer((N, 3), init=V0, name="V")

        def timestep(chunk, p, v):
            """reads all of P, updates its chunk of V (paper L10-L17)."""
            Pa = p.get(Box((0, 0), (N, 3)))
            F = gravity_forces(Pa, chunk.min[0], chunk.max[0])
            v.set(chunk, v.get(chunk) + MASS * F * DT)

        def update(chunk, v, p):
            """reads its chunk of V, updates its chunk of P (paper L19-L25)."""
            p.set(chunk, p.get(chunk) + v.get(chunk) * DT)

        for _ in range(STEPS):
            q.submit("timestep", (N, 3),
                     [read(P, all_range()), read_write(V, one_to_one())],
                     timestep)
            q.submit("update", (N, 3),
                     [read(V, one_to_one()), read_write(P, one_to_one())],
                     update)

        result = q.gather(P)
        print(f"simulated {N} bodies x {STEPS} steps "
              f"on 2 ranks x 2 devices")
        print(f"instructions executed: {q.total_instructions()}, "
              f"P2P bytes: {q.comm.bytes_sent}, "
              f"messages: {q.comm.num_messages}")
        print(f"center of mass drift: "
              f"{np.abs(result.mean(0) - P0.mean(0)).max():.2e}")
        print("\nexecution timeline (fig. 7 style):")
        print(q.tracer.timeline_text(70))


if __name__ == "__main__":
    main()
