"""RSim radiosity pattern: a buffer that grows by one row per time step —
the adversarial case for ad-hoc memory management (paper §4.3 / §5).

Run with and without scheduler lookahead to see resize elision:

    PYTHONPATH=src python examples/rsim_lookahead.py
"""

import time

import numpy as np

from repro.core import Runtime, fixed, read, write
from repro.core.region import Box, Region

T, W = 64, 4096


def row_cols(t):
    def rm(chunk, shape):
        return Region.from_box(Box((t, chunk.min[1]), (t + 1, chunk.max[1])))
    rm.__name__ = f"row_cols({t})"
    return rm


def run(lookahead: bool):
    t0 = time.perf_counter()
    with Runtime(num_nodes=1, devices_per_node=2, lookahead=lookahead) as q:
        R = q.buffer((T, W), init=np.zeros((T, W)), name="radiosity")
        for t in range(T):
            def radiosity(chunk, prev, row, t=t):
                lo, hi = chunk.min[1], chunk.max[1]
                if t == 0:
                    vals = np.ones(hi - lo)
                else:
                    vals = prev.get(Box((0, lo), (t, hi))).sum(0) * 0.5 + 1.0
                row.set(Box((t, lo), (t + 1, hi)), vals)

            q.submit(f"radiosity{t}", Box((0, 0), (1, W)),
                     [read(R, fixed(Box((0, 0), (max(t, 1), W)))),
                      write(R, row_cols(t))],
                     radiosity, split_dims=(1,))
        out = q.gather(R)
        allocs = q.total_allocs()
        stats = q.schedulers[0].lookahead.stats
    wall = time.perf_counter() - t0
    return out, allocs, stats, wall


def main() -> None:
    out_on, allocs_on, stats_on, wall_on = run(lookahead=True)
    out_off, allocs_off, _, wall_off = run(lookahead=False)
    assert np.allclose(out_on, out_off)
    print(f"{T} growing-row steps on 2 devices ({W} cols)")
    print(f"  lookahead ON : {allocs_on:3d} device allocations, "
          f"{wall_on * 1e3:7.1f} ms  (queued {stats_on.commands_queued_peak} "
          f"commands, {stats_on.flushes} flush)")
    print(f"  lookahead OFF: {allocs_off:3d} device allocations, "
          f"{wall_off * 1e3:7.1f} ms  (resize chains: alloc+copy+free per "
          f"step)")
    print("lookahead eliminated "
          f"{allocs_off - allocs_on} resize allocations "
          f"({(1 - allocs_on / allocs_off) * 100:.0f}%)")


if __name__ == "__main__":
    main()
