"""End-to-end driver: train a ~100M-param LM for a few hundred steps on CPU
through the full stack — IDAG-orchestrated loop (prefetch/step/checkpoint
overlap), AdamW, deterministic data pipeline, async sharded checkpointing.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import time
from dataclasses import replace

from repro.configs import get_config
from repro.runtime import TrainLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M-param variant of the assigned architecture (CPU-trainable)
    cfg = replace(get_config(args.arch),
                  num_layers=4, d_model=512, num_heads=8, num_kv_heads=2,
                  d_ff=2048, vocab_size=32768, head_dim=64,
                  param_dtype="float32", dtype="float32")
    n = cfg.param_count()
    print(f"arch={cfg.name} (reduced): {n / 1e6:.1f}M params")

    loop = TrainLoop(cfg, global_batch=8, seq_len=128,
                     ckpt_dir=args.ckpt, ckpt_interval=50, lr=1e-3)
    t0 = time.perf_counter()
    end, state, m = loop.run(args.steps)
    wall = time.perf_counter() - t0
    k = max(len(m.losses) // 10, 1)
    for i in range(0, len(m.losses), k):
        print(f"  step {m.steps[i]:4d}  loss {m.losses[i]:.4f}")
    print(f"  step {m.steps[-1]:4d}  loss {m.losses[-1]:.4f}")
    print(f"{args.steps} steps in {wall:.1f}s "
          f"({wall / args.steps * 1e3:.0f} ms/step); "
          f"loss {m.losses[0]:.3f} -> {m.losses[-1]:.3f}")
    assert m.losses[-1] < m.losses[0]


if __name__ == "__main__":
    main()
